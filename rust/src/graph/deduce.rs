//! §5.2 — Annotation deduction.
//!
//! Leaf ops and CommOps declare their outputs' annotations; everything else
//! is deduced in topological order:
//!
//! * `DG Union`/`HSize` unification (Fig 10): inputs with smaller `HSize`
//!   are *refined* (semantic-preserving subgroup split) to the largest
//!   `HSize`; post-conversion the DG unions must align, otherwise the user
//!   must insert a CommOp;
//! * `DS Union` deduction reduces to per-subgroup SPMD deduction once the
//!   unions align;
//! * `HDim` deduction follows per-operator rules (Fig 11 for `Dot`).

use crate::hspmd::ds::{DistStates, DUPLICATE, PARTIAL};
use crate::hspmd::{Annotation, Subgroup};
use crate::{Error, Result};

use super::{Graph, OpKind, TensorId};

/// Deduce annotations for every tensor of `g` under strategy `k` (§5.2).
pub fn deduce(g: &mut Graph, k: usize) -> Result<()> {
    if k >= g.num_strategies {
        return Err(Error::ded(format!("strategy {k} out of range")));
    }
    for op_idx in 0..g.ops.len() {
        let op = g.ops[op_idx].clone();
        let out_ann: Annotation = match &op.kind {
            OpKind::Placeholder | OpKind::Parameter | OpKind::Comm => {
                op.declared.get(k).and_then(|a| a.clone()).ok_or_else(|| {
                    Error::ded(format!(
                        "op {} (`{}`) has no declared annotation for strategy {k}",
                        op.id,
                        g.tensors[op.outputs[0]].name
                    ))
                })?
            }
            OpKind::Unary(_) | OpKind::ArtifactCall { .. } => input_ann(g, &op.inputs, 0, k)?,
            OpKind::Add => {
                let a = input_ann(g, &op.inputs, 0, k)?;
                let b = input_ann(g, &op.inputs, 1, k)?;
                let (a, b) = unify(&a, &b)?;
                if a.same_ds_union(&b) && a.hdim == b.hdim {
                    a
                } else {
                    return Err(Error::ded(format!(
                        "add: incompatible annotations {} vs {} — insert a CommOp",
                        a.describe(),
                        b.describe()
                    )));
                }
            }
            OpKind::Dot => {
                let x = input_ann(g, &op.inputs, 0, k)?;
                let w = input_ann(g, &op.inputs, 1, k)?;
                let x_rank = g.tensors[op.inputs[0]].shape.len();
                let (x, w) = unify(&x, &w)?;
                deduce_dot(&x, &w, x_rank)?
            }
            OpKind::Sum { dim } => {
                let x = input_ann(g, &op.inputs, 0, k)?;
                deduce_sum(&x, *dim)?
            }
            OpKind::Reshape => {
                let x = input_ann(g, &op.inputs, 0, k)?;
                let ok = x.hdim <= 0
                    && x.groups.iter().all(|s| s.ds.splits().iter().all(|&(d, _)| d == 0));
                if !ok {
                    return Err(Error::ded(
                        "reshape only supports dim-0 sharding (insert a CommOp first)",
                    ));
                }
                x
            }
        };
        for &out in &op.outputs {
            g.tensors[out].annotations[k] = Some(out_ann.clone());
        }
    }
    Ok(())
}

fn input_ann(g: &Graph, inputs: &[TensorId], idx: usize, k: usize) -> Result<Annotation> {
    let t = inputs
        .get(idx)
        .ok_or_else(|| Error::ded(format!("missing input {idx}")))?;
    g.tensors[*t]
        .annotation(k)
        .cloned()
        .ok_or_else(|| Error::ded(format!("input `{}` not yet annotated", g.tensors[*t].name)))
}

/// Fig 10 — unify `HSize`/`DG Union` of two input annotations by refining
/// the smaller-`HSize` side. Errors if no semantic-preserving refinement
/// aligns the unions (the user must insert a CommOp).
pub fn unify(a: &Annotation, b: &Annotation) -> Result<(Annotation, Annotation)> {
    use std::cmp::Ordering;
    match a.hsize().cmp(&b.hsize()) {
        Ordering::Equal => {
            if a.same_dg_union(b) {
                Ok((a.clone(), b.clone()))
            } else {
                Err(Error::ded(format!(
                    "DG unions do not align: {} vs {} — insert a CommOp",
                    a.describe(),
                    b.describe()
                )))
            }
        }
        Ordering::Less => {
            let a2 = refine_to_match(a, b)?;
            Ok((a2, b.clone()))
        }
        Ordering::Greater => {
            let b2 = refine_to_match(b, a)?;
            Ok((a.clone(), b2))
        }
    }
}

/// Refine `small` to `large.hsize()` subgroups such that the DG unions
/// align, trying every logical dim of the DS as the split axis.
fn refine_to_match(small: &Annotation, large: &Annotation) -> Result<Annotation> {
    if large.hsize() % small.hsize() != 0 {
        return Err(Error::ded(format!(
            "HSize {} does not divide {}",
            small.hsize(),
            large.hsize()
        )));
    }
    let k = (large.hsize() / small.hsize()) as u32;
    // candidate logical dims: those present in every subgroup's DS
    let mut candidates: Vec<i32> = vec![DUPLICATE, PARTIAL];
    for sub in &small.groups {
        for &(d, _) in sub.ds.entries() {
            if d >= 0 && !candidates.contains(&d) {
                candidates.push(d);
            }
        }
    }
    for ld in candidates {
        if let Ok(refined) = small.refine(ld, k) {
            if refined.same_dg_union(large) {
                return Ok(refined);
            }
        }
    }
    Err(Error::ded(format!(
        "no semantic-preserving refinement of {} aligns with {} — insert a CommOp",
        small.describe(),
        large.describe()
    )))
}

/// Fig 11 — Dot deduction for `X[..., c] @ W[c, d]` once unions align.
pub fn deduce_dot(x: &Annotation, w: &Annotation, x_rank: usize) -> Result<Annotation> {
    if x_rank < 1 {
        return Err(Error::ded("dot: X rank must be >= 1"));
    }
    let contract = (x_rank - 1) as i32;
    let mut groups = Vec::with_capacity(x.hsize());
    for (sx, sw) in x.groups.iter().zip(w.groups.iter()) {
        if !sx.dg.same_set(&sw.dg) {
            return Err(Error::ded("dot: subgroup DGs differ — insert a CommOp"));
        }
        let n = sx.dg.len() as u32;
        let c = sx.ds.shards(contract);
        if c != sw.ds.shards(0) {
            return Err(Error::ded(format!(
                "dot: contraction sharding mismatch X:{c} vs W:{}",
                sw.ds.shards(0)
            )));
        }
        // Y entries: X's batch splits, W's output split, partial from the
        // contraction (times any incoming partials).
        let mut entries: Vec<(i32, u32)> = vec![];
        for &(d, s) in sx.ds.entries() {
            if d >= 0 && d < contract {
                entries.push((d, s));
            }
        }
        let out_split = sw.ds.shards(1);
        if out_split > 1 {
            entries.push((contract, out_split));
        }
        let partial = c * sx.ds.shards(PARTIAL) * sw.ds.shards(PARTIAL);
        if partial > 1 {
            entries.push((PARTIAL, partial));
        }
        let used: u32 = entries.iter().map(|&(_, s)| s).product();
        if n % used != 0 {
            return Err(Error::ded(format!(
                "dot: sharding covers {used} of {n} devices non-divisibly"
            )));
        }
        let dup = n / used;
        if dup > 1 {
            entries.push((DUPLICATE, dup));
        }
        let ds = DistStates::with_default_order(&entries)?;
        // Keep the *device order* consistent with the input X ordering: the
        // deduction result uses canonical order; strategy lowering declares
        // explicit orders where it matters (see DESIGN.md).
        groups.push(Subgroup::new(sx.dg.clone(), ds)?);
    }
    // HDim rule (Fig 11-right).
    let hdim = match (x.hdim, w.hdim) {
        (DUPLICATE, DUPLICATE) => DUPLICATE,
        (d, DUPLICATE) if d >= 0 && d < contract => d,
        (d, 0) if d == contract => PARTIAL,
        (DUPLICATE, 1) => contract,
        (PARTIAL, DUPLICATE) | (DUPLICATE, PARTIAL) | (PARTIAL, PARTIAL) => PARTIAL,
        (xd, wd) => {
            return Err(Error::ded(format!(
                "dot: unsupported HDim combination X:{xd} W:{wd}"
            )))
        }
    };
    Annotation::with_weights(groups, hdim, if hdim == x.hdim { x.hsplit.clone() } else { None })
}

/// Sum deduction: a split on the reduced dim becomes `Partial`; splits on
/// higher dims shift down.
pub fn deduce_sum(x: &Annotation, dim: u32) -> Result<Annotation> {
    let d = dim as i32;
    let mut groups = Vec::with_capacity(x.hsize());
    for sub in &x.groups {
        let mut entries: Vec<(i32, u32)> = vec![];
        let mut partial = sub.ds.shards(PARTIAL);
        for &(ld, s) in sub.ds.entries() {
            match ld {
                PARTIAL => {}
                DUPLICATE => entries.push((DUPLICATE, s)),
                x if x == d => partial *= s,
                x if x > d => entries.push((x - 1, s)),
                x => entries.push((x, s)),
            }
        }
        if partial > 1 {
            entries.push((PARTIAL, partial));
        }
        groups.push(Subgroup::new(sub.dg.clone(), DistStates::with_default_order(&entries)?)?);
    }
    let hdim = if x.hdim == d {
        PARTIAL
    } else if x.hdim > d {
        x.hdim - 1
    } else {
        x.hdim
    };
    Annotation::with_weights(groups, hdim, if hdim == x.hdim { x.hsplit.clone() } else { None })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{lits, DType, UnaryKind};
    use crate::hspmd::DeviceGroup;

    fn spmd(ranks: std::ops::Range<u32>, entries: &[(i32, u32)], order: &[i32]) -> Annotation {
        Annotation::spmd(
            DeviceGroup::range(ranks.start, ranks.end),
            DistStates::new(entries, order).unwrap(),
        )
        .unwrap()
    }

    /// Fig 2 (left): DP2 × TP2 over GPUs 0-3. X split on batch + contraction
    /// sharded to match W's row split → Y becomes Partial over TP.
    #[test]
    fn fig2_left_dot_produces_partial() {
        let mut g = Graph::new(1);
        let x = g
            .placeholder(
                "X",
                lits(&[8, 16]),
                DType::F32,
                vec![spmd(0..4, &[(0, 2), (1, 2)], &[0, 1])],
            )
            .unwrap();
        let w = g
            .parameter(
                "W",
                lits(&[16, 32]),
                DType::F32,
                vec![spmd(0..4, &[(DUPLICATE, 2), (0, 2)], &[-1, 0])],
            )
            .unwrap();
        let xg = g.unary(UnaryKind::Gelu, x);
        let y = g.dot(xg, w).unwrap();
        deduce(&mut g, 0).unwrap();
        let ann = g.tensor(y).annotation(0).unwrap();
        assert_eq!(ann.hsize(), 1);
        let ds = &ann.groups[0].ds;
        assert_eq!(ds.shards(0), 2, "batch split preserved: {}", ds.describe());
        assert_eq!(ds.shards(PARTIAL), 2, "contraction became partial: {}", ds.describe());
        assert_eq!(ds.shards(1), 1);
    }

    /// Fig 11 golden: 3-D X (a,b,c splits) × 2-D W (c,d splits) → Y with
    /// partial = c, splits a,b,d.
    #[test]
    fn fig11_ds_rule() {
        // n = 16 devices: a=2, b=1, c=2, d=2, dup... X uses a*c*dupx, W c*d*dupw.
        let x = spmd(0..16, &[(0, 2), (2, 2), (DUPLICATE, 4)], &[0, 2, -1]);
        let w = spmd(0..16, &[(0, 2), (1, 2), (DUPLICATE, 4)], &[0, 1, -1]);
        let y = deduce_dot(&x, &w, 3).unwrap();
        let ds = &y.groups[0].ds;
        assert_eq!(ds.shards(0), 2); // a
        assert_eq!(ds.shards(1), 1); // b unsharded
        assert_eq!(ds.shards(2), 2); // d
        assert_eq!(ds.shards(PARTIAL), 2); // c
        assert_eq!(ds.shards(DUPLICATE), 2); // n/(a*c*d) = 16/8
    }

    /// Fig 11 (right) HDim table.
    #[test]
    fn fig11_hdim_rules() {
        let mk = |hdim: i32, entries: &[(i32, u32)]| {
            let g0 = Subgroup::new(
                DeviceGroup::range(0, 2),
                DistStates::with_default_order(entries).unwrap(),
            )
            .unwrap();
            let g1 = Subgroup::new(
                DeviceGroup::range(2, 4),
                DistStates::with_default_order(entries).unwrap(),
            )
            .unwrap();
            Annotation::new(vec![g0, g1], hdim).unwrap()
        };
        let dup = |hdim: i32| mk(hdim, &[(DUPLICATE, 2)]);
        // (X -1, W -1) -> -1
        assert_eq!(deduce_dot(&dup(-1), &dup(-1), 3).unwrap().hdim, -1);
        // (X 0, W -1) -> 0
        assert_eq!(deduce_dot(&dup(0), &dup(-1), 3).unwrap().hdim, 0);
        // (X 1, W -1) -> 1
        assert_eq!(deduce_dot(&dup(1), &dup(-1), 3).unwrap().hdim, 1);
        // (X 2 = contraction, W 0) -> -2 (partial across subgroups)
        assert_eq!(deduce_dot(&dup(2), &mk(0, &[(DUPLICATE, 2)]), 3).unwrap().hdim, -2);
        // (X -1, W 1) -> 2 (output split across subgroups)
        assert_eq!(deduce_dot(&dup(-1), &mk(1, &[(DUPLICATE, 2)]), 3).unwrap().hdim, 2);
        // unsupported combination errors
        assert!(deduce_dot(&dup(0), &mk(1, &[(DUPLICATE, 2)]), 3).is_err());
    }

    #[test]
    fn unify_refines_smaller_hsize() {
        // Fig 10: W with hsize 1 replicated over 4 devices unifies with an
        // X split into 2 subgroups of 2.
        let w = spmd(0..4, &[(DUPLICATE, 2), (0, 2)], &[-1, 0]);
        let g0 = Subgroup::new(DeviceGroup::range(0, 2), DistStates::split(0, 2)).unwrap();
        let g1 = Subgroup::new(DeviceGroup::range(2, 4), DistStates::split(0, 2)).unwrap();
        let x = Annotation::new(vec![g0, g1], 0).unwrap();
        let (x2, w2) = unify(&x, &w).unwrap();
        assert_eq!(x2.hsize(), 2);
        assert_eq!(w2.hsize(), 2);
        assert!(w2.same_dg_union(&x2));
    }

    #[test]
    fn unify_fails_without_alignment() {
        let a = spmd(0..2, &[(0, 2)], &[0]);
        let b = spmd(2..4, &[(0, 2)], &[0]);
        assert!(unify(&a, &b).is_err());
    }

    #[test]
    fn sum_turns_split_into_partial() {
        let x = spmd(0..4, &[(0, 2), (1, 2)], &[0, 1]);
        let y = deduce_sum(&x, 0).unwrap();
        let ds = &y.groups[0].ds;
        assert_eq!(ds.shards(PARTIAL), 2);
        assert_eq!(ds.shards(0), 2, "dim1 shifted to dim0: {}", ds.describe());
    }

    #[test]
    fn sum_shifts_hdim() {
        let g0 = Subgroup::new(DeviceGroup::range(0, 1), DistStates::trivial()).unwrap();
        let g1 = Subgroup::new(DeviceGroup::range(1, 2), DistStates::trivial()).unwrap();
        let x = Annotation::new(vec![g0, g1], 2).unwrap();
        assert_eq!(deduce_sum(&x, 0).unwrap().hdim, 1);
        let x2 = Annotation::new(x.groups.clone(), 1).unwrap();
        assert_eq!(deduce_sum(&x2, 1).unwrap().hdim, PARTIAL);
    }

    #[test]
    fn comm_op_declares_new_annotation() {
        let mut g = Graph::new(1);
        let w = g
            .parameter("W", lits(&[16, 32]), DType::F32, vec![spmd(0..2, &[(0, 2)], &[0])])
            .unwrap();
        let target = spmd(0..2, &[(1, 2)], &[1]);
        let w2 = g.comm(w, vec![target.clone()]).unwrap();
        deduce(&mut g, 0).unwrap();
        assert_eq!(g.tensor(w2).annotation(0).unwrap(), &target);
    }

    #[test]
    fn deduction_requires_declared_leaves() {
        let mut g = Graph::new(2);
        let x = g
            .placeholder("X", lits(&[4]), DType::F32, vec![
                spmd(0..2, &[(0, 2)], &[0]),
                spmd(0..2, &[(0, 2)], &[0]),
            ])
            .unwrap();
        let _ = g.unary(UnaryKind::Gelu, x);
        // strategy 2 added but never declared
        g.add_strategy();
        assert!(deduce(&mut g, 2).is_err());
    }
}
