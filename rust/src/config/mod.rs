//! Run configuration + dependency-free CLI parsing.
//!
//! The build image cannot fetch `clap`, so the launcher uses a small
//! hand-rolled parser: `--key value` / `--key=value` / bare flags, with
//! typed accessors and helpful errors.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line: a subcommand plus options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// First positional argument (subcommand).
    pub command: String,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// `--key value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Cli {
    /// Parse from an argument list (excluding argv[0]).
    pub fn parse(args: &[String]) -> Cli {
        let mut cli = Cli::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    cli.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                    cli.options.insert(stripped.to_string(), args[i + 1].clone());
                    i += 1;
                } else {
                    cli.flags.push(stripped.to_string());
                }
            } else if cli.command.is_empty() {
                cli.command = a.clone();
            } else {
                cli.positional.push(a.clone());
            }
            i += 1;
        }
        cli
    }

    /// String option with default.
    pub fn str_opt(&self, key: &str, default: &str) -> String {
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Integer option with default.
    pub fn u64_opt(&self, key: &str, default: u64) -> Result<u64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    /// Float option with default.
    pub fn f64_opt(&self, key: &str, default: f64) -> Result<f64> {
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Config(format!("--{key} expects a number, got `{v}`"))),
        }
    }

    /// Flag presence.
    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// Training-run configuration for the engine/coordinator.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Artifact directory (HLO text files).
    pub artifacts_dir: String,
    /// Model preset name.
    pub model: String,
    /// Steps to run.
    pub steps: u64,
    /// Global batch (samples per step).
    pub global_batch: u64,
    /// Sequence length.
    pub seq_len: u64,
    /// Learning rate.
    pub lr: f64,
    /// Simulated devices for the engine.
    pub num_devices: u32,
    /// RNG seed.
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".into(),
            model: "tiny-100m".into(),
            steps: 20,
            global_batch: 8,
            seq_len: 128,
            lr: 3e-4,
            num_devices: 4,
            seed: 42,
        }
    }
}

impl RunConfig {
    /// Build from CLI options.
    pub fn from_cli(cli: &Cli) -> Result<RunConfig> {
        let d = RunConfig::default();
        Ok(RunConfig {
            artifacts_dir: cli.str_opt("artifacts", &d.artifacts_dir),
            model: cli.str_opt("model", &d.model),
            steps: cli.u64_opt("steps", d.steps)?,
            global_batch: cli.u64_opt("global-batch", d.global_batch)?,
            seq_len: cli.u64_opt("seq-len", d.seq_len)?,
            lr: cli.f64_opt("lr", d.lr)?,
            num_devices: cli.u64_opt("devices", d.num_devices as u64)? as u32,
            seed: cli.u64_opt("seed", d.seed)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let cli = Cli::parse(&args(&["train", "--steps", "100", "--model=tiny", "--verbose"]));
        assert_eq!(cli.command, "train");
        assert_eq!(cli.u64_opt("steps", 0).unwrap(), 100);
        assert_eq!(cli.str_opt("model", ""), "tiny");
        assert!(cli.has_flag("verbose"));
    }

    #[test]
    fn bad_int_errors() {
        let cli = Cli::parse(&args(&["x", "--steps", "abc"]));
        assert!(cli.u64_opt("steps", 0).is_err());
    }

    #[test]
    fn run_config_defaults() {
        let cli = Cli::parse(&args(&["train"]));
        let rc = RunConfig::from_cli(&cli).unwrap();
        assert_eq!(rc.steps, 20);
        assert_eq!(rc.num_devices, 4);
    }

    #[test]
    fn positional_args_collected() {
        let cli = Cli::parse(&args(&["bench", "fig13", "fig14"]));
        assert_eq!(cli.positional, vec!["fig13", "fig14"]);
    }
}
