//! Analytical transformer cost model.
//!
//! The paper's headline figures compare *per-step training time* of parallel
//! strategies on 32B/70B Llama models. We reproduce the comparisons on a
//! simulated cluster, so compute/memory/communication are derived
//! analytically:
//!
//! * dense FLOPs ≈ `2 · P_layer · tokens` per layer and direction (bwd = 2×
//!   fwd), plus the attention `O(s²)` term;
//! * memory = parameters + gradients + optimizer states (sharded by ZeRO
//!   degree) + activations (with/without checkpointing);
//! * communication volumes follow §2.1: TP all-reduces per layer, PP
//!   boundary sends, DP gradient synchronization.
//!
//! Absolute times are *not* expected to match the paper's H800/H20 wall
//! clocks; strategy *rankings and ratios* are (DESIGN.md §2).

use crate::cluster::DeviceKind;

/// Model architecture description (Llama family).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModelCfg {
    /// Human name ("llama-32b").
    pub name: &'static str,
    /// Transformer layer count.
    pub layers: u32,
    /// Hidden size.
    pub hidden: u64,
    /// FFN inner size.
    pub ffn: u64,
    /// Attention heads.
    pub heads: u32,
    /// Vocabulary size.
    pub vocab: u64,
}

impl ModelCfg {
    /// The paper's 32B model: 60 layers (Appendix tables use L0–59).
    pub fn llama_32b() -> ModelCfg {
        ModelCfg { name: "llama-32b", layers: 60, hidden: 6400, ffn: 25600, heads: 50, vocab: 32000 }
    }

    /// The paper's 70B model: 80 layers (L0–79).
    pub fn llama_70b() -> ModelCfg {
        // ffn chosen so the 2-matrix FFN approximation matches ~70B total
        // (real Llama-70B uses a 3-matrix SwiGLU of 28672).
        ModelCfg { name: "llama-70b", layers: 80, hidden: 8192, ffn: 36864, heads: 64, vocab: 32000 }
    }

    /// A 7B configuration (extra experiments).
    pub fn llama_7b() -> ModelCfg {
        ModelCfg { name: "llama-7b", layers: 32, hidden: 4096, ffn: 11008, heads: 32, vocab: 32000 }
    }

    /// ~100M-parameter config for the real-numerics end-to-end example.
    pub fn tiny_100m() -> ModelCfg {
        ModelCfg { name: "tiny-100m", layers: 8, hidden: 768, ffn: 3072, heads: 12, vocab: 32000 }
    }

    /// Parameters per transformer layer (attention 4h² + MLP 2·h·ffn for the
    /// gate-free approximation used throughout).
    pub fn params_per_layer(&self) -> u64 {
        4 * self.hidden * self.hidden + 2 * self.hidden * self.ffn
    }

    /// Total parameters (layers + tied embedding).
    pub fn params(&self) -> u64 {
        self.params_per_layer() * self.layers as u64 + self.vocab * self.hidden
    }

    /// Forward FLOPs for `tokens` tokens through `layers` layers at
    /// sequence length `seq` (dense + causal attention term).
    pub fn fwd_flops(&self, layers: u32, tokens: u64, seq: u64) -> f64 {
        let dense = 2.0 * self.params_per_layer() as f64 * tokens as f64;
        // causal attention: 2 matmuls of s×s×h per sequence → 2·2·s·h per
        // token, halved by causality.
        let attn = 2.0 * seq as f64 * self.hidden as f64 * tokens as f64;
        (dense + attn) * layers as f64
    }

    /// Backward FLOPs (2× forward).
    pub fn bwd_flops(&self, layers: u32, tokens: u64, seq: u64) -> f64 {
        2.0 * self.fwd_flops(layers, tokens, seq)
    }

    /// LM-head + embedding forward FLOPs.
    pub fn head_flops(&self, tokens: u64) -> f64 {
        2.0 * self.vocab as f64 * self.hidden as f64 * tokens as f64
    }
}

/// Execution-efficiency knobs.
#[derive(Clone, Copy, Debug)]
pub struct CostParams {
    /// Model FLOPS utilization (fraction of peak actually achieved).
    pub mfu: f64,
    /// Bytes per element of activations/weights on the wire (bf16).
    pub elem_bytes: f64,
    /// Activation-checkpointing recompute multiplier on backward
    /// (1.0 = off; with AC backward effectively reruns the forward).
    pub ac_recompute: f64,
    /// Fixed per-kernel / per-op launch overhead folded into each task (s).
    pub task_overhead_s: f64,
    /// Utilization ramp: effective MFU scales by `tokens/(tokens + ramp)` —
    /// small micro-batches under-utilize the tensor cores, which is why the
    /// paper's strategies prefer bs2 when memory allows.
    pub mfu_ramp_tokens: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            mfu: 0.45,
            elem_bytes: 2.0,
            ac_recompute: 1.0,
            task_overhead_s: 40e-6,
            mfu_ramp_tokens: 1024.0,
        }
    }
}

/// The cost model: per-task compute times and communication volumes.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Architecture.
    pub model: ModelCfg,
    /// Efficiency parameters.
    pub params: CostParams,
}

impl CostModel {
    /// Build with default efficiency parameters.
    pub fn new(model: ModelCfg) -> CostModel {
        CostModel { model, params: CostParams::default() }
    }

    /// Seconds of forward compute for a micro-batch of `tokens` tokens on
    /// `layers` layers, TP degree `tp`, on device `dev`.
    pub fn fwd_s(&self, dev: &DeviceKind, layers: u32, tokens: u64, seq: u64, tp: u32) -> f64 {
        let flops = self.model.fwd_flops(layers, tokens, seq) / tp as f64;
        let ramp = tokens as f64 / (tokens as f64 + self.params.mfu_ramp_tokens);
        flops / (dev.bf16_tflops * 1e12 * self.params.mfu * ramp) + self.params.task_overhead_s
    }

    /// Seconds of backward compute (2× fwd, plus AC recompute).
    pub fn bwd_s(&self, dev: &DeviceKind, layers: u32, tokens: u64, seq: u64, tp: u32) -> f64 {
        let mult = 2.0 + (self.params.ac_recompute - 1.0);
        mult * self.fwd_s(dev, layers, tokens, seq, tp) - self.params.task_overhead_s * (mult - 1.0)
    }

    /// TP activation-sync bytes per layer per direction for a micro-batch of
    /// `tokens` tokens (Megatron: 2 all-reduces of `tokens·h` elements).
    pub fn tp_sync_bytes(&self, tokens: u64) -> u64 {
        (2.0 * tokens as f64 * self.model.hidden as f64 * self.params.elem_bytes) as u64
    }

    /// Pipeline boundary payload (activations) for a micro-batch.
    pub fn pp_boundary_bytes(&self, tokens: u64) -> u64 {
        (tokens as f64 * self.model.hidden as f64 * self.params.elem_bytes) as u64
    }

    /// Per-device gradient bytes for `layers` layers at TP degree `tp`
    /// (fp32 gradient sync unless bf16 grads; we use elem_bytes).
    pub fn grad_bytes(&self, layers: u32, tp: u32) -> u64 {
        (self.model.params_per_layer() as f64 * layers as f64 / tp as f64 * self.params.elem_bytes)
            as u64
    }

    /// Peak memory (GiB) for a device holding `layers` layers at TP `tp`,
    /// DP-sharding optimizer states over `zero_dp` ways, batch of `tokens`
    /// tokens per resident micro-batch, `resident_mb` micro-batches of
    /// activations live (1F1B keeps ≤ num_stages), with/without AC.
    pub fn device_mem_gib(
        &self,
        layers: u32,
        tp: u32,
        zero_dp: u32,
        tokens_per_mb: u64,
        resident_mb: u32,
        ac: bool,
    ) -> f64 {
        let p = self.model.params_per_layer() as f64 * layers as f64 / tp as f64;
        let weights = 2.0 * p; // bf16
        let grads = 2.0 * p;
        let opt = 12.0 * p / zero_dp as f64; // fp32 master + 2 moments, ZeRO-sharded
        // activations per token per layer: ~34·h bytes (Megatron), AC keeps
        // only the boundary (~2·h per token per layer).
        let act_per_token = if ac { 2.0 } else { 34.0 } * self.model.hidden as f64 / tp as f64;
        let act = act_per_token * tokens_per_mb as f64 * layers as f64 * resident_mb as f64;
        (weights + grads + opt + act) / (1u64 << 30) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{H20, H800};

    #[test]
    fn param_counts_are_plausible() {
        let m32 = ModelCfg::llama_32b();
        let p32 = m32.params() as f64 / 1e9;
        assert!((25.0..40.0).contains(&p32), "32B model has {p32}B params");
        let m70 = ModelCfg::llama_70b();
        let p70 = m70.params() as f64 / 1e9;
        assert!((60.0..80.0).contains(&p70), "70B model has {p70}B params");
        let t = ModelCfg::tiny_100m();
        let pt = t.params() as f64 / 1e6;
        assert!((50.0..200.0).contains(&pt), "tiny model has {pt}M params");
    }

    #[test]
    fn h800_is_faster_than_h20() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let f800 = cm.fwd_s(&H800, 10, 4096, 4096, 1);
        let f20 = cm.fwd_s(&H20, 10, 4096, 4096, 1);
        assert!(f20 > 4.0 * f800, "H20 {f20} vs H800 {f800}: ~6.7x flops gap");
    }

    #[test]
    fn tp_divides_compute() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let t1 = cm.fwd_s(&H800, 10, 4096, 4096, 1);
        let t4 = cm.fwd_s(&H800, 10, 4096, 4096, 4);
        assert!(t4 < t1 / 3.0 && t4 > t1 / 5.0);
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let f = cm.fwd_s(&H800, 10, 4096, 4096, 1);
        let b = cm.bwd_s(&H800, 10, 4096, 4096, 1);
        assert!((b / f - 2.0).abs() < 0.05);
    }

    #[test]
    fn ac_adds_recompute() {
        let mut cm = CostModel::new(ModelCfg::llama_32b());
        let b0 = cm.bwd_s(&H800, 10, 4096, 4096, 1);
        cm.params.ac_recompute = 2.0;
        let b1 = cm.bwd_s(&H800, 10, 4096, 4096, 1);
        assert!(b1 > b0 * 1.4);
    }

    #[test]
    fn memory_decreases_with_tp_and_zero() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let m_tp1 = cm.device_mem_gib(60, 1, 1, 4096, 1, true);
        let m_tp4 = cm.device_mem_gib(60, 4, 1, 4096, 1, true);
        let m_tp4_z8 = cm.device_mem_gib(60, 4, 8, 4096, 1, true);
        assert!(m_tp4 < m_tp1 / 3.0);
        assert!(m_tp4_z8 < m_tp4);
        // whole 32B on one GPU does not fit 80 GiB
        assert!(m_tp1 > 80.0);
    }

    #[test]
    fn ac_cuts_activation_memory() {
        let cm = CostModel::new(ModelCfg::llama_32b());
        let with_ac = cm.device_mem_gib(15, 4, 8, 8192, 4, true);
        let without = cm.device_mem_gib(15, 4, 8, 8192, 4, false);
        assert!(without > with_ac);
    }

    #[test]
    fn attention_term_grows_quadratically() {
        let m = ModelCfg::llama_32b();
        // same token budget, longer sequences → more FLOPs
        let short = m.fwd_flops(60, 200_000, 4096);
        let long = m.fwd_flops(60, 200_000, 32768);
        assert!(long > short * 1.2);
    }
}
