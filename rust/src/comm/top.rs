//! §4.2 — Top-tier communication (across sharding subgroups).
//!
//! Applies when `HSize` matches and the `DG Union`s are set-equivalent but
//! `HDim` differs. With equal `DS Union`s the transformation is a *split
//! collective* over the finest-grained slices (Fig 6); otherwise a
//! bottom-tier DS-alignment pass runs first (Fig 7).

use crate::hspmd::ds::{DUPLICATE, PARTIAL};
use crate::hspmd::slices::{regions, DeviceRegion, SliceGrid};
use crate::hspmd::Annotation;
use crate::{Error, Result};

use super::plan::{CollKind, CollectiveOp, CommPlan, ResolvedKind};

/// Which split collective (if any) realizes an `HDim` change with unchanged
/// `DS Union` (the Fig 6 table; other combinations are unsupported and fall
/// through to BSR).
pub fn split_kind(src_hdim: i32, dst_hdim: i32) -> Option<CollKind> {
    match (src_hdim, dst_hdim) {
        (PARTIAL, DUPLICATE) => Some(CollKind::AllReduce),
        (PARTIAL, d) if d >= 0 => Some(CollKind::ReduceScatter),
        (d, DUPLICATE) if d >= 0 => Some(CollKind::AllGather),
        _ => None,
    }
}

/// Build the slice-granularity cross-subgroup collectives for an `HDim`
/// change with equal `DS Union`s.
///
/// For every finest-grained slice, the participating devices are matched
/// *by replica index within each subgroup* (replicas of the same slice in
/// different subgroups form one collective group). Returns
/// [`Error::UnsupportedComm`] if subgroups hold unequal replica counts for
/// some slice — such layouts have no symmetric collective decomposition.
pub fn split_collectives(
    src: &Annotation,
    dst: &Annotation,
    shape: &[u64],
) -> Result<(Vec<CollectiveOp>, ResolvedKind)> {
    let kind = split_kind(src.hdim, dst.hdim).ok_or_else(|| {
        Error::UnsupportedComm(format!(
            "no split collective for hdim {} -> {}",
            src.hdim, dst.hdim
        ))
    })?;
    let resolved = match kind {
        CollKind::AllReduce => ResolvedKind::SplitAllReduce,
        CollKind::ReduceScatter => ResolvedKind::SplitReduceScatter,
        CollKind::AllGather => ResolvedKind::SplitAllGather,
    };
    let dim = match kind {
        CollKind::ReduceScatter => Some(dst.hdim as u32),
        CollKind::AllGather => Some(src.hdim as u32),
        CollKind::AllReduce => None,
    };
    let src_regions = regions(src, shape)?;
    let dst_regions = regions(dst, shape)?;
    let grid = SliceGrid::build(shape, &[&src_regions, &dst_regions]);

    let mut ops = Vec::new();
    for slice in grid.slices() {
        // Participants per subgroup: devices whose src (for reductions) or
        // src∪dst (for gathers) region contains the slice.
        let mut per_group: Vec<Vec<&DeviceRegion>> = vec![vec![]; src.hsize()];
        match kind {
            CollKind::AllReduce | CollKind::ReduceScatter => {
                for dr in &src_regions {
                    if covers(dr, &slice) {
                        per_group[dr.subgroup].push(dr);
                    }
                }
            }
            CollKind::AllGather => {
                // gather: the owner subgroup contributes the slice, every
                // subgroup that needs it (dst) participates.
                for dr in &src_regions {
                    if covers(dr, &slice) {
                        per_group[dr.subgroup].push(dr);
                    }
                }
                for dr in &dst_regions {
                    if covers(dr, &slice)
                        && !per_group[dr.subgroup].iter().any(|x| x.rank == dr.rank)
                    {
                        per_group[dr.subgroup].push(dr);
                    }
                }
            }
        }
        let active: Vec<&Vec<&DeviceRegion>> =
            per_group.iter().filter(|v| !v.is_empty()).collect();
        if active.len() <= 1 {
            continue; // slice lives in a single subgroup — no cross-group op
        }
        let replicas = active[0].len();
        if kind != CollKind::AllGather && active.iter().any(|v| v.len() != replicas) {
            return Err(Error::UnsupportedComm(format!(
                "unequal replica counts across subgroups for slice {slice:?}"
            )));
        }
        let max_rep = active.iter().map(|v| v.len()).max().unwrap();
        for j in 0..max_rep {
            let mut group: Vec<u32> = active
                .iter()
                .filter_map(|v| v.get(j.min(v.len() - 1)).map(|d| d.rank))
                .collect();
            group.sort_unstable();
            group.dedup();
            if group.len() > 1 {
                ops.push(CollectiveOp { kind, group, slice: slice.clone(), dim });
            }
            if kind == CollKind::AllGather && max_rep == 1 {
                break;
            }
        }
    }
    Ok((ops, resolved))
}

fn covers(dr: &DeviceRegion, slice: &crate::hspmd::slices::Region) -> bool {
    dr.region.iter().zip(slice.iter()).all(|(a, b)| a.contains(b))
}

/// Fig 7 — the intermediate annotation for a combined transformation:
/// destination `DS Union` under the *source* top tier.
pub fn alignment_midpoint(src: &Annotation, dst: &Annotation) -> Result<Annotation> {
    let groups = src
        .groups
        .iter()
        .zip(dst.groups.iter())
        .map(|(s, d)| crate::hspmd::Subgroup::new(s.dg.clone(), d.ds.clone()))
        .collect::<Result<Vec<_>>>()?;
    Annotation::with_weights(groups, src.hdim, src.hsplit.clone())
}

/// Expose [`CommPlan`] assembly for the resolver: a pure top-tier plan.
pub fn top_plan(ops: Vec<CollectiveOp>) -> CommPlan {
    CommPlan::Collective { ops, top_tier: true }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hspmd::{DeviceGroup, DistStates, Subgroup};

    /// Two subgroups of 2 devices each, bottom split on dim0.
    fn pair(hdim: i32) -> Annotation {
        let g0 = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![2, 3]).unwrap(), DistStates::split(0, 2)).unwrap();
        Annotation::new(vec![g0, g1], hdim).unwrap()
    }

    #[test]
    fn split_kind_table() {
        assert_eq!(split_kind(PARTIAL, DUPLICATE), Some(CollKind::AllReduce));
        assert_eq!(split_kind(PARTIAL, 1), Some(CollKind::ReduceScatter));
        assert_eq!(split_kind(0, DUPLICATE), Some(CollKind::AllGather));
        assert_eq!(split_kind(0, 1), None);
        assert_eq!(split_kind(DUPLICATE, PARTIAL), None);
    }

    #[test]
    fn split_allreduce_pairs_matching_shards() {
        // hdim -2 → -1 with identical bottom sharding: device i of each
        // subgroup holds the same slice → AR groups {0,2} and {1,3}.
        let src = pair(PARTIAL);
        let dst = pair(DUPLICATE);
        let (ops, kind) = split_collectives(&src, &dst, &[8, 4]).unwrap();
        assert_eq!(kind, ResolvedKind::SplitAllReduce);
        let groups: Vec<Vec<u32>> = ops.iter().map(|o| o.group.clone()).collect();
        assert!(groups.contains(&vec![0, 2]), "{groups:?}");
        assert!(groups.contains(&vec![1, 3]), "{groups:?}");
    }

    #[test]
    fn split_allreduce_finest_granularity_mismatched_ds() {
        // subgroup 0 splits dim0 in 2, subgroup 1 holds it whole (1 device):
        // the single device of subgroup 1 joins both slice-level ARs (Fig 6).
        let g0 = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![5]).unwrap(), DistStates::trivial()).unwrap();
        let src = Annotation::new(vec![g0.clone(), g1.clone()], PARTIAL).unwrap();
        let dst = Annotation::new(vec![g0, g1], DUPLICATE).unwrap();
        let (ops, _) = split_collectives(&src, &dst, &[8]).unwrap();
        assert_eq!(ops.len(), 2);
        assert!(ops.iter().all(|o| o.group.contains(&5)));
        assert!(ops.iter().any(|o| o.group.contains(&0)));
        assert!(ops.iter().any(|o| o.group.contains(&1)));
    }

    #[test]
    fn split_reduce_scatter_targets_dst_dim() {
        let src = pair(PARTIAL);
        let dst = pair(1);
        let (ops, kind) = split_collectives(&src, &dst, &[8, 4]).unwrap();
        assert_eq!(kind, ResolvedKind::SplitReduceScatter);
        assert!(ops.iter().all(|o| o.kind == CollKind::ReduceScatter && o.dim == Some(1)));
    }

    #[test]
    fn split_allgather_spans_subgroups() {
        let src = pair(1); // subgroups own halves of dim1
        let dst = pair(DUPLICATE);
        let (ops, kind) = split_collectives(&src, &dst, &[8, 4]).unwrap();
        assert_eq!(kind, ResolvedKind::SplitAllGather);
        assert!(!ops.is_empty());
        for op in &ops {
            assert!(op.group.len() >= 2);
        }
    }

    #[test]
    fn alignment_midpoint_swaps_ds() {
        let src = pair(PARTIAL);
        let mut dst = pair(DUPLICATE);
        dst.groups[0] = Subgroup::new(
            DeviceGroup::new(vec![0, 1]).unwrap(),
            DistStates::split(1, 2),
        )
        .unwrap();
        let mid = alignment_midpoint(&src, &dst).unwrap();
        assert_eq!(mid.hdim, PARTIAL);
        assert_eq!(mid.groups[0].ds, DistStates::split(1, 2));
        assert_eq!(mid.groups[1].ds, DistStates::split(0, 2));
    }
}
