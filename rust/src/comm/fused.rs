//! §6.2 — Fused BSR: one global plan for multi-tensor repartitioning.
//!
//! Strategy switching repartitions *every* parameter tensor at once. Instead
//! of planning each tensor's BSR independently, the fused planner:
//!
//! 1. consolidates all per-tensor BSR tables into one, sharing the
//!    cumulative-send-load tracker so heuristic (3) balances the entire
//!    transition, and
//! 2. fuses all slice transfers between the same `(sender, receiver)` pair
//!    into a single message, minimizing kernel-launch latency.

use std::collections::HashMap;

use crate::hspmd::dg::Rank;
use crate::hspmd::slices::Region;
use crate::hspmd::Annotation;
use crate::Result;

use super::bsr::{Bandwidth, BsrOptions, LoadTracker};

/// One tensor that must move from its source sharding to its destination
/// sharding during a strategy switch.
#[derive(Clone, Debug)]
pub struct TensorMove {
    /// Stable tensor name (parameter path).
    pub name: String,
    /// Source annotation (current strategy).
    pub src: Annotation,
    /// Destination annotation (next strategy).
    pub dst: Annotation,
    /// Global tensor shape.
    pub shape: Vec<u64>,
    /// Bytes per element (2 = bf16, 4 = fp32).
    pub elem_bytes: u64,
}

/// A fused message: every slice (possibly of many tensors) moving between
/// one device pair, sent as a single batched send-receive.
#[derive(Clone, Debug)]
pub struct FusedMessage {
    /// Sender rank.
    pub from: Rank,
    /// Receiver rank.
    pub to: Rank,
    /// `(tensor index, slice)` payload items.
    pub items: Vec<(usize, Region)>,
    /// Total payload bytes.
    pub bytes: u64,
}

/// The full transition plan.
#[derive(Clone, Debug, Default)]
pub struct FusedBsrPlan {
    /// Cross-device messages (fused per device pair when `fuse` is on).
    pub messages: Vec<FusedMessage>,
    /// `(rank, tensor index, slice)` satisfied locally.
    pub local_copies: Vec<(Rank, usize, Region)>,
}

impl FusedBsrPlan {
    /// Total bytes on the wire.
    pub fn wire_bytes(&self) -> u64 {
        self.messages.iter().map(|m| m.bytes).sum()
    }

    /// Number of send-receive launches (the fusion objective).
    pub fn num_messages(&self) -> usize {
        self.messages.len()
    }

    /// Per-sender `(intra-node bytes, inter-node bytes)` — the Table 2 rows.
    pub fn sender_volumes(&self, bw: &dyn Bandwidth) -> HashMap<Rank, (u64, u64)> {
        let mut out: HashMap<Rank, (u64, u64)> = HashMap::new();
        for m in &self.messages {
            let e = out.entry(m.from).or_insert((0, 0));
            if bw.intra_node(m.from, m.to) {
                e.0 += m.bytes;
            } else {
                e.1 += m.bytes;
            }
        }
        out
    }

    /// The transition's bottleneck: the maximum per-link transfer time, in
    /// seconds, assuming per-message serialization on each directed link
    /// plus a fixed per-message launch overhead.
    pub fn bottleneck_seconds(&self, bw: &dyn Bandwidth, launch_overhead_s: f64) -> f64 {
        let mut per_link: HashMap<(Rank, Rank), f64> = HashMap::new();
        for m in &self.messages {
            let t = m.bytes as f64 / (bw.gbps(m.from, m.to) * 1e9) + launch_overhead_s;
            *per_link.entry((m.from, m.to)).or_insert(0.0) += t;
        }
        // A device sends sequentially: sum over its outgoing links, then the
        // slowest device bounds the transition.
        let mut per_sender: HashMap<Rank, f64> = HashMap::new();
        for ((from, _), t) in per_link {
            *per_sender.entry(from).or_insert(0.0) += t;
        }
        per_sender.values().copied().fold(0.0, f64::max)
    }
}

/// Plan a multi-tensor transition.
///
/// * `opts.heuristics` — enable sender-selection heuristics (2)+(3);
/// * `fuse` — share the load tracker across tensors and merge same-pair
///   transfers into single messages (the paper's optimized planner). With
///   `fuse = false` each tensor is planned in isolation and every slice is
///   its own message (the "unfused" baseline of Fig 18-right).
pub fn plan_transition(
    moves: &[TensorMove],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    fuse: bool,
) -> Result<FusedBsrPlan> {
    plan_transition_avoiding(moves, bw, opts, fuse, &[])
}

/// [`plan_transition`] with failed devices excluded as senders (§7.2: a
/// dead rank's slices are sourced from surviving replicas).
pub fn plan_transition_avoiding(
    moves: &[TensorMove],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    fuse: bool,
    dead: &[Rank],
) -> Result<FusedBsrPlan> {
    let mut plan = FusedBsrPlan::default();
    let mut shared_loads = LoadTracker::default();
    let mut pair_index: HashMap<(Rank, Rank), usize> = HashMap::new();

    for (ti, mv) in moves.iter().enumerate() {
        let mut local_loads = LoadTracker::default();
        let loads = if fuse { &mut shared_loads } else { &mut local_loads };
        let tensor_plan =
            super::bsr::plan_bsr_excluding(&mv.src, &mv.dst, &mv.shape, bw, opts, loads, dead)?;
        for (rank, slice) in tensor_plan.local_copies {
            plan.local_copies.push((rank, ti, slice));
        }
        for t in tensor_plan.transfers {
            let bytes = t.elems() * mv.elem_bytes;
            if fuse {
                let idx = *pair_index.entry((t.from, t.to)).or_insert_with(|| {
                    plan.messages.push(FusedMessage {
                        from: t.from,
                        to: t.to,
                        items: vec![],
                        bytes: 0,
                    });
                    plan.messages.len() - 1
                });
                plan.messages[idx].items.push((ti, t.slice));
                plan.messages[idx].bytes += bytes;
            } else {
                plan.messages.push(FusedMessage {
                    from: t.from,
                    to: t.to,
                    items: vec![(ti, t.slice)],
                    bytes,
                });
            }
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::UniformBandwidth;
    use crate::hspmd::{DeviceGroup, DistStates};

    fn mv(name: &str, src_ranks: Vec<Rank>, dst_ranks: Vec<Rank>, n: u64) -> TensorMove {
        TensorMove {
            name: name.into(),
            src: Annotation::spmd(
                DeviceGroup::new(src_ranks.clone()).unwrap(),
                DistStates::split(0, src_ranks.len() as u32),
            )
            .unwrap(),
            dst: Annotation::spmd(
                DeviceGroup::new(dst_ranks.clone()).unwrap(),
                DistStates::split(0, dst_ranks.len() as u32),
            )
            .unwrap(),
            shape: vec![n],
            elem_bytes: 4,
        }
    }

    #[test]
    fn fusion_reduces_message_count_not_volume() {
        let moves = vec![
            mv("w1", vec![0, 1], vec![2, 3], 8),
            mv("w2", vec![0, 1], vec![2, 3], 8),
            mv("w3", vec![0, 1], vec![2, 3], 8),
        ];
        let unfused =
            plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), false).unwrap();
        let fused = plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), true).unwrap();
        assert_eq!(unfused.wire_bytes(), fused.wire_bytes());
        assert!(fused.num_messages() < unfused.num_messages());
        // fused: at most one message per (from,to) pair
        let mut pairs: Vec<(Rank, Rank)> = fused.messages.iter().map(|m| (m.from, m.to)).collect();
        pairs.sort_unstable();
        let len = pairs.len();
        pairs.dedup();
        assert_eq!(pairs.len(), len);
    }

    #[test]
    fn shared_loads_balance_across_tensors() {
        // Two owners replicated; many single-needer tensors. With fusion the
        // load tracker alternates senders; unfused always picks the same one
        // (both have zero load at the start of each tensor's plan).
        let dup = |ranks: Vec<Rank>| {
            Annotation::spmd(
                DeviceGroup::new(ranks.clone()).unwrap(),
                DistStates::duplicate(ranks.len() as u32),
            )
            .unwrap()
        };
        let single =
            |r: Rank| Annotation::spmd(DeviceGroup::new(vec![r]).unwrap(), DistStates::trivial()).unwrap();
        let moves: Vec<TensorMove> = (0..6)
            .map(|i| TensorMove {
                name: format!("t{i}"),
                src: dup(vec![0, 1]),
                dst: single(5),
                shape: vec![16],
                elem_bytes: 4,
            })
            .collect();
        let fused = plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), true).unwrap();
        let vols = fused.sender_volumes(&UniformBandwidth);
        let v0 = vols.get(&0).map(|v| v.0).unwrap_or(0);
        let v1 = vols.get(&1).map(|v| v.0).unwrap_or(0);
        assert_eq!(v0, v1, "fused planner should balance senders: {vols:?}");

        let unfused =
            plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), false).unwrap();
        let uvols = unfused.sender_volumes(&UniformBandwidth);
        assert!(
            uvols.get(&1).is_none() || uvols.get(&0).is_none(),
            "unfused planner lacks cross-tensor balance: {uvols:?}"
        );
    }

    #[test]
    fn bottleneck_improves_with_fusion() {
        let moves: Vec<TensorMove> =
            (0..8).map(|i| mv(&format!("w{i}"), vec![0, 1], vec![2, 3], 1 << 16)).collect();
        let unfused =
            plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), false).unwrap();
        let fused = plan_transition(&moves, &UniformBandwidth, BsrOptions::default(), true).unwrap();
        let overhead = 1e-3;
        assert!(
            fused.bottleneck_seconds(&UniformBandwidth, overhead)
                < unfused.bottleneck_seconds(&UniformBandwidth, overhead)
        );
    }
}
