//! §4.3 — Batched-send-receive (BSR).
//!
//! When neither bottom-tier nor top-tier collectives apply, any
//! re-partitioning that involves no `Partial` values decomposes into point-
//! to-point transfers of the finest-grained slices. The plan is built from a
//! *BSR table* (slice → owners → needers) and the paper's three sender-
//! selection heuristics:
//!
//! 1. **local copy** for slices the needer already owns;
//! 2. prefer the owner with the **highest bandwidth** to the receiver;
//! 3. tie-break on the **lowest cumulative send load** (then lowest rank id
//!    for determinism).

use std::collections::HashMap;

use crate::hspmd::dg::Rank;
use crate::hspmd::slices::{region_elems, regions, DeviceRegion, Region, SliceGrid};
use crate::hspmd::Annotation;
use crate::{Error, Result};

/// Bandwidth oracle used by heuristic (2). Implemented by
/// [`crate::cluster::Topology`]; [`UniformBandwidth`] is the trivial stand-in.
pub trait Bandwidth {
    /// Link bandwidth in GB/s between two devices (same-device = +inf
    /// conceptually; callers never query self-links).
    fn gbps(&self, from: Rank, to: Rank) -> f64;

    /// True if the pair communicates over the intra-node fabric (NVLink in
    /// the paper's cluster) rather than the inter-node network (IB).
    fn intra_node(&self, from: Rank, to: Rank) -> bool;
}

/// All links equal — reduces heuristic (2) to a no-op.
pub struct UniformBandwidth;

impl Bandwidth for UniformBandwidth {
    fn gbps(&self, _from: Rank, _to: Rank) -> f64 {
        1.0
    }
    fn intra_node(&self, _from: Rank, _to: Rank) -> bool {
        true
    }
}

/// One point-to-point slice transfer.
#[derive(Clone, Debug, PartialEq)]
pub struct Transfer {
    /// Slice of the global tensor being moved.
    pub slice: Region,
    /// Sender rank.
    pub from: Rank,
    /// Receiver rank.
    pub to: Rank,
}

impl Transfer {
    /// Payload size in elements.
    pub fn elems(&self) -> u64 {
        region_elems(&self.slice)
    }
}

/// Planner options — the Fig 18-right / Table 2 ablation axes.
#[derive(Clone, Copy, Debug)]
pub struct BsrOptions {
    /// Apply heuristics (2) bandwidth and (3) load balancing. When false,
    /// the sender is always the minimal rank id (the paper's "w/o
    /// heuristics" baseline). Heuristic (1) — local copy — always applies:
    /// it is a correctness-level optimization.
    pub heuristics: bool,
}

impl Default for BsrOptions {
    fn default() -> Self {
        BsrOptions { heuristics: true }
    }
}

/// A complete BSR plan for one tensor.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct BsrPlan {
    /// Cross-device transfers.
    pub transfers: Vec<Transfer>,
    /// Slices satisfied by local memory copy (heuristic 1).
    pub local_copies: Vec<(Rank, Region)>,
}

impl BsrPlan {
    /// Total elements moved across devices.
    pub fn wire_elems(&self) -> u64 {
        self.transfers.iter().map(|t| t.elems()).sum()
    }

    /// Per-sender wire volume in elements, split (intra-node, inter-node).
    pub fn sender_volumes(&self, bw: &dyn Bandwidth) -> HashMap<Rank, (u64, u64)> {
        let mut out: HashMap<Rank, (u64, u64)> = HashMap::new();
        for t in &self.transfers {
            let e = out.entry(t.from).or_insert((0, 0));
            if bw.intra_node(t.from, t.to) {
                e.0 += t.elems();
            } else {
                e.1 += t.elems();
            }
        }
        out
    }
}

/// Mutable sender-load state; shared across tensors by the §6.2 fused
/// planner so heuristic (3) balances the *whole* transition.
#[derive(Default, Clone, Debug)]
pub struct LoadTracker {
    send_elems: HashMap<Rank, u64>,
}

impl LoadTracker {
    /// Current cumulative load of `rank` in elements.
    pub fn load(&self, rank: Rank) -> u64 {
        self.send_elems.get(&rank).copied().unwrap_or(0)
    }

    /// Account `elems` sent by `rank`.
    pub fn add(&mut self, rank: Rank, elems: u64) {
        *self.send_elems.entry(rank).or_insert(0) += elems;
    }
}

/// Build the BSR table and plan for a single tensor.
///
/// Errors with [`Error::UnsupportedComm`] if either side involves `Partial`
/// values (§4.3 *Discussions*: BSR cannot reduce).
pub fn plan_bsr(
    src: &Annotation,
    dst: &Annotation,
    shape: &[u64],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    loads: &mut LoadTracker,
) -> Result<BsrPlan> {
    plan_bsr_excluding(src, dst, shape, bw, opts, loads, &[])
}

/// [`plan_bsr`] with `dead` ranks removed from the *source* side (failed
/// devices cannot send; surviving replicas must cover their slices).
pub fn plan_bsr_excluding(
    src: &Annotation,
    dst: &Annotation,
    shape: &[u64],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    loads: &mut LoadTracker,
    dead: &[Rank],
) -> Result<BsrPlan> {
    if src.has_partial() || dst.has_partial() {
        return Err(Error::UnsupportedComm(format!(
            "BSR cannot handle Partial tensors (src {}, dst {})",
            src.describe(),
            dst.describe()
        )));
    }
    let mut src_regions = regions(src, shape)?;
    if !dead.is_empty() {
        src_regions.retain(|r| !dead.contains(&r.rank));
    }
    let dst_regions = regions(dst, shape)?;
    plan_bsr_regions(&src_regions, &dst_regions, shape, bw, opts, loads)
}

/// BSR planning over precomputed region lists (used by the fused planner).
pub fn plan_bsr_regions(
    src_regions: &[DeviceRegion],
    dst_regions: &[DeviceRegion],
    shape: &[u64],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
    loads: &mut LoadTracker,
) -> Result<BsrPlan> {
    let grid = SliceGrid::build(shape, &[src_regions, dst_regions]);
    let mut plan = BsrPlan::default();
    for slice in grid.slices() {
        let owners = SliceGrid::holders(&slice, src_regions);
        let needers = SliceGrid::holders(&slice, dst_regions);
        if needers.is_empty() {
            continue; // slice dropped by the destination sharding
        }
        if owners.is_empty() {
            return Err(Error::UnsupportedComm(format!(
                "slice {slice:?} required by destination but owned by no source device"
            )));
        }
        let elems = region_elems(&slice);
        for needer in needers {
            // Heuristic (1): local copy.
            if owners.iter().any(|o| o.rank == needer.rank) {
                plan.local_copies.push((needer.rank, slice.clone()));
                continue;
            }
            let sender = if opts.heuristics {
                select_sender(&owners, needer.rank, bw, loads)
            } else {
                owners.iter().map(|o| o.rank).min().unwrap()
            };
            loads.add(sender, elems);
            plan.transfers.push(Transfer { slice: slice.clone(), from: sender, to: needer.rank });
        }
    }
    Ok(plan)
}

/// Heuristics (2)+(3): highest bandwidth to the receiver, then lowest
/// cumulative send load, then lowest rank id.
fn select_sender(
    owners: &[&DeviceRegion],
    to: Rank,
    bw: &dyn Bandwidth,
    loads: &LoadTracker,
) -> Rank {
    let mut best: Option<(f64, u64, Rank)> = None;
    for o in owners {
        let g = bw.gbps(o.rank, to);
        let l = loads.load(o.rank);
        let cand = (g, l, o.rank);
        best = Some(match best {
            None => cand,
            Some(b) => {
                // prefer higher bandwidth; then lower load; then lower rank
                if cand.0 > b.0 || (cand.0 == b.0 && (cand.1 < b.1 || (cand.1 == b.1 && cand.2 < b.2))) {
                    cand
                } else {
                    b
                }
            }
        });
    }
    best.unwrap().2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hspmd::{DeviceGroup, DistStates};

    fn spmd(ranks: Vec<Rank>, ds: DistStates) -> Annotation {
        Annotation::spmd(DeviceGroup::new(ranks).unwrap(), ds).unwrap()
    }

    struct TwoNodes;
    impl Bandwidth for TwoNodes {
        fn gbps(&self, from: Rank, to: Rank) -> f64 {
            if self.intra_node(from, to) {
                400.0
            } else {
                25.0
            }
        }
        fn intra_node(&self, from: Rank, to: Rank) -> bool {
            (from < 8) == (to < 8)
        }
    }

    #[test]
    fn rejects_partial() {
        let src = spmd(vec![0, 1], DistStates::partial(2));
        let dst = spmd(vec![0, 1], DistStates::duplicate(2));
        let mut lt = LoadTracker::default();
        assert!(plan_bsr(&src, &dst, &[4], &UniformBandwidth, BsrOptions::default(), &mut lt).is_err());
    }

    #[test]
    fn local_copy_when_owned() {
        // split 2 -> same split 2 on same devices: all local copies
        let src = spmd(vec![0, 1], DistStates::split(0, 2));
        let dst = spmd(vec![0, 1], DistStates::split(0, 2));
        let mut lt = LoadTracker::default();
        let plan =
            plan_bsr(&src, &dst, &[8], &UniformBandwidth, BsrOptions::default(), &mut lt).unwrap();
        assert!(plan.transfers.is_empty());
        assert_eq!(plan.local_copies.len(), 2);
    }

    #[test]
    fn repartition_2_to_4() {
        // split 2 over {0,1} -> split 4 over {0,1,2,3}
        let src = spmd(vec![0, 1], DistStates::split(0, 2));
        let dst = spmd(vec![0, 1, 2, 3], DistStates::split(0, 4));
        let mut lt = LoadTracker::default();
        let plan =
            plan_bsr(&src, &dst, &[8], &UniformBandwidth, BsrOptions::default(), &mut lt).unwrap();
        // dst quarters: rank0 [0,2) local; rank1 [2,4) from rank0;
        // rank2 [4,6) and rank3 [6,8) from rank1.
        assert_eq!(plan.local_copies.len(), 1);
        assert_eq!(plan.local_copies[0].0, 0);
        assert_eq!(plan.transfers.len(), 3);
        let mut tos: Vec<Rank> = plan.transfers.iter().map(|t| t.to).collect();
        tos.sort_unstable();
        assert_eq!(tos, vec![1, 2, 3]);
        assert_eq!(plan.wire_elems(), 6);
    }

    #[test]
    fn bandwidth_heuristic_prefers_intra_node() {
        // slice replicated on ranks 1 (node 0) and 9 (node 1); needer 8 (node 1)
        // → rank 9 should send (Fig 8 heuristic 2).
        let src = spmd(vec![1, 9], DistStates::duplicate(2));
        let dst = spmd(vec![8], DistStates::trivial());
        let mut lt = LoadTracker::default();
        let plan = plan_bsr(&src, &dst, &[4], &TwoNodes, BsrOptions::default(), &mut lt).unwrap();
        assert_eq!(plan.transfers.len(), 1);
        assert_eq!(plan.transfers[0].from, 9);
    }

    #[test]
    fn no_heuristics_picks_min_rank() {
        let src = spmd(vec![1, 9], DistStates::duplicate(2));
        let dst = spmd(vec![8], DistStates::trivial());
        let mut lt = LoadTracker::default();
        let plan =
            plan_bsr(&src, &dst, &[4], &TwoNodes, BsrOptions { heuristics: false }, &mut lt)
                .unwrap();
        assert_eq!(plan.transfers[0].from, 1);
    }

    #[test]
    fn load_balancing_tiebreak() {
        // Fig 8 heuristic 3: two equal-bandwidth owners {0,1}, two needers
        // {2,3} of two different slices → senders alternate.
        let src = spmd(vec![0, 1], DistStates::duplicate(2));
        let dst = spmd(vec![2, 3], DistStates::split(0, 2));
        let mut lt = LoadTracker::default();
        let plan =
            plan_bsr(&src, &dst, &[8], &UniformBandwidth, BsrOptions::default(), &mut lt).unwrap();
        assert_eq!(plan.transfers.len(), 2);
        let froms: std::collections::BTreeSet<Rank> =
            plan.transfers.iter().map(|t| t.from).collect();
        assert_eq!(froms.len(), 2, "load should balance across both owners: {plan:?}");
    }

    #[test]
    fn volume_accounting_splits_fabrics() {
        let src = spmd(vec![0], DistStates::trivial());
        let dst = spmd(vec![1, 9], DistStates::split(0, 2));
        let mut lt = LoadTracker::default();
        let plan = plan_bsr(&src, &dst, &[8], &TwoNodes, BsrOptions::default(), &mut lt).unwrap();
        let vols = plan.sender_volumes(&TwoNodes);
        let (nv, ib) = vols[&0];
        assert_eq!(nv, 4); // to rank 1, intra-node
        assert_eq!(ib, 4); // to rank 9, inter-node
    }

    #[test]
    fn destination_fully_covered() {
        // random-ish repartition: every dst element must be produced exactly once
        let src = spmd(vec![0, 1, 2], DistStates::split(0, 3));
        let dst = spmd(vec![3, 4], DistStates::split(1, 2));
        let mut lt = LoadTracker::default();
        let plan =
            plan_bsr(&src, &dst, &[6, 4], &UniformBandwidth, BsrOptions::default(), &mut lt)
                .unwrap();
        let moved: u64 = plan.wire_elems()
            + plan.local_copies.iter().map(|(_, r)| region_elems(r)).sum::<u64>();
        assert_eq!(moved, 24); // 6*4 elements, each delivered once
    }
}
