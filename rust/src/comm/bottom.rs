//! §4.1 — Bottom-tier communication (within sharding subgroups).

use crate::hspmd::ds::{DistStates, DUPLICATE, PARTIAL};
use crate::hspmd::slices::regions;
use crate::hspmd::{Annotation, Subgroup};
use crate::Result;

use super::bsr::{Bandwidth, BsrOptions, LoadTracker, Transfer};
use super::plan::{CollKind, CollectiveOp, CommPlan, ResolvedKind};

/// Resolve one sharding subgroup's transformation (same top-tier context on
/// both sides). Returns the plan plus the Fig 4 classification.
pub fn resolve_subgroup(
    src_top: &Annotation,
    dst_top: &Annotation,
    g: usize,
    shape: &[u64],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
) -> Result<(CommPlan, ResolvedKind)> {
    let s = &src_top.groups[g];
    let d = &dst_top.groups[g];

    if s.ds == d.ds {
        if s.dg == d.dg {
            // Case (I): identical DS + identical DG → identity.
            return Ok((CommPlan::Identity, ResolvedKind::Identity));
        }
        if s.dg.len() == d.dg.len() {
            // Case (I): identical DS, device list changed → pairwise SR of
            // each local shard (position i sends to position i).
            let src_regions = sub_regions(src_top, g, shape)?;
            let pairs: Vec<Transfer> = s
                .dg
                .ranks()
                .iter()
                .zip(d.dg.ranks().iter())
                .zip(src_regions.iter())
                .filter(|((a, b), _)| a != b)
                .map(|((a, b), r)| Transfer { slice: r.clone(), from: *a, to: *b })
                .collect();
            if pairs.is_empty() {
                return Ok((CommPlan::Identity, ResolvedKind::Identity));
            }
            return Ok((CommPlan::SendRecv(pairs), ResolvedKind::SendRecv));
        }
        // size change with equal DS is impossible (|DG| == DS devices)
        unreachable!("equal DS implies equal subgroup size");
    }

    // Case (II): DS changed. Collectives require the same device *set*.
    if s.dg.same_set(&d.dg) {
        if let Some(t) = find_transition(&s.ds, &d.ds) {
            match (t.0, t.1) {
                (PARTIAL, DUPLICATE) => {
                    let ops = collective_ops(s, src_top, g, shape, CollKind::AllReduce, None)?;
                    return Ok((CommPlan::Collective { ops, top_tier: false }, ResolvedKind::AllReduce));
                }
                (PARTIAL, dim) if dim >= 0 => {
                    let ops =
                        collective_ops(s, src_top, g, shape, CollKind::ReduceScatter, Some(dim as u32))?;
                    return Ok((
                        CommPlan::Collective { ops, top_tier: false },
                        ResolvedKind::ReduceScatter,
                    ));
                }
                (dim, DUPLICATE) if dim >= 0 => {
                    let ops =
                        collective_ops(s, src_top, g, shape, CollKind::AllGather, Some(dim as u32))?;
                    return Ok((CommPlan::Collective { ops, top_tier: false }, ResolvedKind::AllGather));
                }
                _ => {}
            }
        }
    }

    // Fallback: BSR within the subgroup (also covers DG set changes).
    let src_sub = single_group_annot(src_top, g)?;
    let dst_sub = single_group_annot(dst_top, g)?;
    let mut loads = LoadTracker::default();
    let plan = super::bsr::plan_bsr(&src_sub, &dst_sub, shape, bw, opts, &mut loads)?;
    Ok((CommPlan::Bsr(plan), ResolvedKind::Bsr))
}

/// Find a single logical-dim relabel `(from, to)` that turns `src` into
/// `dst` (Fig 5): `PARTIAL→DUPLICATE` (AR), `PARTIAL→d` (RS), `d→DUPLICATE`
/// (AG). Uses [`DistStates::relabel`], which correctly merges into an
/// existing `DUPLICATE` entry (e.g. `{-1:2,-2:2} → {-1:4}`).
fn find_transition(src: &DistStates, dst: &DistStates) -> Option<(i32, i32)> {
    let mut candidates: Vec<(i32, i32)> = vec![(PARTIAL, DUPLICATE)];
    for (d, _) in dst.splits() {
        candidates.push((PARTIAL, d as i32));
    }
    for (d, _) in src.splits() {
        candidates.push((d as i32, DUPLICATE));
    }
    for (from, to) in candidates {
        if src.shards(from) > 1 {
            if let Ok(relabelled) = src.relabel(from, to) {
                if &relabelled == dst {
                    return Some((from, to));
                }
            }
        }
    }
    None
}

/// Device regions of subgroup `g` under the full annotation (top-tier box
/// applied), in subgroup device order.
fn sub_regions(
    annot: &Annotation,
    g: usize,
    shape: &[u64],
) -> Result<Vec<crate::hspmd::slices::Region>> {
    let all = regions(annot, shape)?;
    Ok(all.into_iter().filter(|r| r.subgroup == g).map(|r| r.region).collect())
}

/// Extract subgroup `g` as a standalone single-group annotation whose
/// geometry matches the full annotation (top-tier interval materialized as
/// a bound `hsplit` weight so regions stay identical).
fn single_group_annot(annot: &Annotation, g: usize) -> Result<Annotation> {
    // Build a 1-group annotation. To preserve the subgroup's top-tier box we
    // re-express it with the same hdim but hsize=1; the regions of the
    // subgroup must be recomputed with the original top interval, so instead
    // of hsize=1 we keep the full structure but only this group... The
    // simplest faithful construction: keep annotation as-is and filter
    // regions at the caller. Here we only need it for BSR planning, so we
    // materialize regions directly.
    Ok(Annotation {
        groups: vec![annot.groups[g].clone()],
        hdim: annot.hdim,
        hsplit: None,
    })
}

/// Build bottom-tier collectives for subgroup `s` of the annotation: one op
/// per group-along-PARTIAL (AR/RS) or group-along-`dim` (AG). The op's slice
/// is the common box of the group's members.
fn collective_ops(
    s: &Subgroup,
    src_top: &Annotation,
    g: usize,
    shape: &[u64],
    kind: CollKind,
    dim: Option<u32>,
) -> Result<Vec<CollectiveOp>> {
    let rs = sub_regions(src_top, g, shape)?;
    let along = match kind {
        CollKind::AllReduce | CollKind::ReduceScatter => PARTIAL,
        CollKind::AllGather => dim.unwrap() as i32,
    };
    let groups = s.ds.groups_along(along);
    let mut ops = Vec::with_capacity(groups.len());
    for positions in groups {
        if positions.len() <= 1 {
            continue;
        }
        let ranks: Vec<u32> = positions.iter().map(|&p| s.dg.ranks()[p]).collect();
        // For AR/RS the members share the same box (they differ only in the
        // PARTIAL coord). For AG the members tile `dim`; the op's slice is
        // their union along that dim.
        let mut slice = rs[positions[0]].clone();
        if kind == CollKind::AllGather {
            let d = dim.unwrap() as usize;
            let lo = positions.iter().map(|&p| rs[p][d].lo).min().unwrap();
            let hi = positions.iter().map(|&p| rs[p][d].hi).max().unwrap();
            slice[d] = crate::hspmd::Interval { lo, hi };
        }
        ops.push(CollectiveOp { kind, group: ranks, slice, dim });
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::UniformBandwidth;
    use crate::hspmd::{DeviceGroup, DistStates};

    fn spmd(ranks: Vec<u32>, ds: DistStates) -> Annotation {
        Annotation::spmd(DeviceGroup::new(ranks).unwrap(), ds).unwrap()
    }

    fn resolve1(src: &Annotation, dst: &Annotation, shape: &[u64]) -> (CommPlan, ResolvedKind) {
        resolve_subgroup(src, dst, 0, shape, &UniformBandwidth, BsrOptions::default()).unwrap()
    }

    #[test]
    fn identity_when_equal() {
        let a = spmd(vec![0, 1], DistStates::split(0, 2));
        let (p, k) = resolve1(&a, &a.clone(), &[4, 4]);
        assert_eq!(p, CommPlan::Identity);
        assert_eq!(k, ResolvedKind::Identity);
    }

    #[test]
    fn send_recv_on_device_change() {
        let src = spmd(vec![0, 1], DistStates::split(0, 2));
        let dst = spmd(vec![0, 2], DistStates::split(0, 2));
        let (p, k) = resolve1(&src, &dst, &[4, 4]);
        assert_eq!(k, ResolvedKind::SendRecv);
        match p {
            CommPlan::SendRecv(ts) => {
                assert_eq!(ts.len(), 1);
                assert_eq!((ts[0].from, ts[0].to), (1, 2));
                assert_eq!(ts[0].elems(), 8);
            }
            other => panic!("expected SendRecv, got {other:?}"),
        }
    }

    #[test]
    fn allreduce_partial_to_dup() {
        let src = spmd(vec![0, 1, 2, 3], DistStates::new(&[(PARTIAL, 2), (0, 2)], &[-2, 0]).unwrap());
        let dst = spmd(vec![0, 1, 2, 3], DistStates::new(&[(DUPLICATE, 2), (0, 2)], &[-1, 0]).unwrap());
        let (p, k) = resolve1(&src, &dst, &[8, 4]);
        assert_eq!(k, ResolvedKind::AllReduce);
        match p {
            CommPlan::Collective { ops, top_tier } => {
                assert!(!top_tier);
                assert_eq!(ops.len(), 2); // one AR group per split shard
                for op in &ops {
                    assert_eq!(op.kind, CollKind::AllReduce);
                    assert_eq!(op.group.len(), 2);
                }
            }
            other => panic!("expected Collective, got {other:?}"),
        }
    }

    #[test]
    fn reduce_scatter_partial_to_split() {
        let src = spmd(vec![0, 1], DistStates::partial(2));
        let dst = spmd(vec![0, 1], DistStates::split(0, 2));
        let (p, k) = resolve1(&src, &dst, &[8]);
        assert_eq!(k, ResolvedKind::ReduceScatter);
        match p {
            CommPlan::Collective { ops, .. } => {
                assert_eq!(ops[0].kind, CollKind::ReduceScatter);
                assert_eq!(ops[0].dim, Some(0));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn all_gather_split_to_dup() {
        let src = spmd(vec![0, 1, 2, 3], DistStates::split(1, 4));
        let dst = spmd(vec![0, 1, 2, 3], DistStates::duplicate(4));
        let (p, k) = resolve1(&src, &dst, &[2, 8]);
        assert_eq!(k, ResolvedKind::AllGather);
        match p {
            CommPlan::Collective { ops, .. } => {
                assert_eq!(ops.len(), 1);
                assert_eq!(ops[0].group, vec![0, 1, 2, 3]);
                // AG slice covers the full gathered extent
                assert_eq!(ops[0].slice[1].len(), 8);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn bsr_fallback_on_resplit() {
        // split dim0 → split dim1: no single collective matches
        let src = spmd(vec![0, 1], DistStates::split(0, 2));
        let dst = spmd(vec![0, 1], DistStates::split(1, 2));
        let (p, k) = resolve1(&src, &dst, &[4, 4]);
        assert_eq!(k, ResolvedKind::Bsr);
        match p {
            CommPlan::Bsr(plan) => {
                // each device keeps its quadrant-diagonal locally, swaps the other
                assert_eq!(plan.local_copies.len(), 2);
                assert_eq!(plan.transfers.len(), 2);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ar_groups_follow_order() {
        // order [0, -2]: split outer, partial inner → AR groups are
        // consecutive pairs.
        let src = spmd(vec![0, 1, 2, 3], DistStates::new(&[(0, 2), (PARTIAL, 2)], &[0, -2]).unwrap());
        let dst = spmd(vec![0, 1, 2, 3], DistStates::new(&[(0, 2), (DUPLICATE, 2)], &[0, -1]).unwrap());
        let (p, _) = resolve1(&src, &dst, &[8]);
        match p {
            CommPlan::Collective { ops, .. } => {
                let groups: Vec<Vec<u32>> = ops.iter().map(|o| o.group.clone()).collect();
                assert!(groups.contains(&vec![0, 1]));
                assert!(groups.contains(&vec![2, 3]));
            }
            other => panic!("{other:?}"),
        }
    }
}
