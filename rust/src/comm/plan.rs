//! Communication plan representation shared by the resolver, the simulator
//! and the real-numerics engine.

use crate::hspmd::dg::Rank;
use crate::hspmd::slices::{region_elems, Region};

use super::bsr::BsrPlan;

/// Collective kinds used by bottom- and top-tier resolution.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum CollKind {
    /// `Partial → Duplicate` (Fig 5 AR).
    AllReduce,
    /// `Partial → Split(d)` (Fig 5 RS).
    ReduceScatter,
    /// `Split(d) → Duplicate` (Fig 5 AG).
    AllGather,
}

impl std::fmt::Display for CollKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            CollKind::AllReduce => "AR",
            CollKind::ReduceScatter => "RS",
            CollKind::AllGather => "AG",
        };
        write!(f, "{s}")
    }
}

/// One collective over an explicit device group and a tensor slice.
///
/// For bottom-tier ops the slice is the subgroup box the group shares; for
/// top-tier `Split*` ops it is one finest-grained slice (Fig 6) and the
/// group has one or more member per subgroup.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveOp {
    /// What to run.
    pub kind: CollKind,
    /// Participating ranks, deterministic order.
    pub group: Vec<Rank>,
    /// The slice of the global tensor the group communicates.
    pub slice: Region,
    /// Physical dim being scattered/gathered (RS/AG only).
    pub dim: Option<u32>,
}

impl CollectiveOp {
    /// Payload elements moved per participant (ring-model accounting):
    /// AR ≈ 2·(n-1)/n·|slice|, RS/AG ≈ (n-1)/n·|slice|.
    pub fn elems_on_wire(&self) -> u64 {
        let n = self.group.len() as u64;
        if n <= 1 {
            return 0;
        }
        let e = region_elems(&self.slice);
        match self.kind {
            CollKind::AllReduce => 2 * e * (n - 1) / n,
            CollKind::ReduceScatter | CollKind::AllGather => e * (n - 1) / n,
        }
    }
}

/// A resolved communication plan.
#[derive(Clone, Debug, PartialEq)]
pub enum CommPlan {
    /// No data movement (annotations identical).
    Identity,
    /// Point-to-point shard transfers (same DS, different device lists).
    SendRecv(Vec<super::bsr::Transfer>),
    /// A set of collectives. `top_tier = true` marks the §4.2 `Split*`
    /// variants (the ops then run *across* sharding subgroups).
    Collective {
        /// The collective ops (independent groups; can run concurrently).
        ops: Vec<CollectiveOp>,
        /// True for SplitAR/SplitRS/SplitAG.
        top_tier: bool,
    },
    /// Batched-send-receive fallback (§4.3).
    Bsr(BsrPlan),
    /// Independent per-subgroup plans (bottom tier, §4.1) — execute
    /// concurrently.
    Parallel(Vec<CommPlan>),
    /// Ordered phases (Fig 7: bottom-tier alignment then top-tier).
    Seq(Vec<CommPlan>),
}

impl CommPlan {
    /// Total elements on the wire (recursive accounting).
    pub fn elems_on_wire(&self) -> u64 {
        match self {
            CommPlan::Identity => 0,
            CommPlan::SendRecv(ts) => ts.iter().map(|t| t.elems()).sum(),
            CommPlan::Collective { ops, .. } => ops.iter().map(|o| o.elems_on_wire()).sum(),
            CommPlan::Bsr(p) => p.transfers.iter().map(|t| t.elems()).sum(),
            CommPlan::Parallel(ps) | CommPlan::Seq(ps) => {
                ps.iter().map(|p| p.elems_on_wire()).sum()
            }
        }
    }

    /// Flatten to the leaf plans (for inspection / execution scheduling).
    pub fn leaves(&self) -> Vec<&CommPlan> {
        match self {
            CommPlan::Parallel(ps) | CommPlan::Seq(ps) => {
                ps.iter().flat_map(|p| p.leaves()).collect()
            }
            leaf => vec![leaf],
        }
    }
}

/// Classification label (Fig 4) — what the resolver decided. Used by the
/// Fig 17 case study and golden tests.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum ResolvedKind {
    /// No communication.
    Identity,
    /// Bottom-tier send-receive.
    SendRecv,
    /// Bottom-tier all-reduce.
    AllReduce,
    /// Bottom-tier reduce-scatter.
    ReduceScatter,
    /// Bottom-tier all-gather.
    AllGather,
    /// Bottom-tier batched-send-receive.
    Bsr,
    /// Mixed bottom-tier kinds across subgroups.
    MixedBottom,
    /// Top-tier split-all-reduce.
    SplitAllReduce,
    /// Top-tier split-reduce-scatter.
    SplitReduceScatter,
    /// Top-tier split-all-gather.
    SplitAllGather,
    /// Bottom-tier alignment followed by a top-tier split collective (Fig 7).
    BottomThenTop,
}

impl std::fmt::Display for ResolvedKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            ResolvedKind::Identity => "Identity",
            ResolvedKind::SendRecv => "SR",
            ResolvedKind::AllReduce => "AR",
            ResolvedKind::ReduceScatter => "RS",
            ResolvedKind::AllGather => "AG",
            ResolvedKind::Bsr => "BSR",
            ResolvedKind::MixedBottom => "BC-mixed",
            ResolvedKind::SplitAllReduce => "SplitAR",
            ResolvedKind::SplitReduceScatter => "SplitRS",
            ResolvedKind::SplitAllGather => "SplitAG",
            ResolvedKind::BottomThenTop => "BC+Split*",
        };
        write!(f, "{s}")
    }
}
