//! The Fig 4 classification pipeline: annotation pair → communication plan.

use crate::hspmd::Annotation;
use crate::{Error, Result};

use super::bottom::resolve_subgroup;
use super::bsr::{plan_bsr, Bandwidth, BsrOptions, LoadTracker};
use super::plan::{CommPlan, ResolvedKind};
use super::top::{alignment_midpoint, split_collectives, split_kind, top_plan};

/// The resolver's output: a plan plus its Fig 4 classification.
#[derive(Clone, Debug)]
pub struct Resolution {
    /// Executable communication plan.
    pub plan: CommPlan,
    /// Classification label (for the Fig 17 case study and tests).
    pub kind: ResolvedKind,
}

/// Resolve the communication realizing `src → dst` over a tensor of
/// concrete `shape` (§4). Follows the heuristic classification of Fig 4:
///
/// 1. same `HSize` + same `HDim` → bottom-tier per subgroup (§4.1);
/// 2. same `HSize` + set-equal `DG Union`, different `HDim`:
///    * equal `DS Union` → SplitAR/SplitRS/SplitAG (§4.2-I);
///    * different `DS Union` → bottom-tier alignment, then split collective
///      (§4.2-II);
/// 3. anything else → BSR (§4.3), which requires `Partial`-free tensors.
pub fn resolve(
    src: &Annotation,
    dst: &Annotation,
    shape: &[u64],
    bw: &dyn Bandwidth,
    opts: BsrOptions,
) -> Result<Resolution> {
    // Case 1 — top tier unchanged: resolve each subgroup independently.
    if src.hsize() == dst.hsize() && src.hdim == dst.hdim && src.hsplit == dst.hsplit {
        let mut plans = Vec::with_capacity(src.hsize());
        let mut kinds = Vec::with_capacity(src.hsize());
        for g in 0..src.hsize() {
            let (p, k) = resolve_subgroup(src, dst, g, shape, bw, opts)?;
            if k != ResolvedKind::Identity {
                plans.push(p);
            }
            kinds.push(k);
        }
        if plans.is_empty() {
            return Ok(Resolution { plan: CommPlan::Identity, kind: ResolvedKind::Identity });
        }
        let non_id: Vec<ResolvedKind> =
            kinds.into_iter().filter(|k| *k != ResolvedKind::Identity).collect();
        let kind = if non_id.iter().all(|k| *k == non_id[0]) {
            non_id[0]
        } else {
            ResolvedKind::MixedBottom
        };
        let plan = if plans.len() == 1 { plans.pop().unwrap() } else { CommPlan::Parallel(plans) };
        return Ok(Resolution { plan, kind });
    }

    // Case 2 — HDim changed over the same DG union.
    if src.hsize() == dst.hsize() && src.same_dg_union(dst) && split_kind(src.hdim, dst.hdim).is_some()
    {
        if src.same_ds_union(dst) {
            let (ops, kind) = split_collectives(src, dst, shape)?;
            return Ok(Resolution { plan: top_plan(ops), kind });
        }
        // Fig 7: align DS unions first (bottom tier), then split collective.
        let mid = alignment_midpoint(src, dst)?;
        let bottom = resolve(src, &mid, shape, bw, opts)?;
        let (ops, _) = split_collectives(&mid, dst, shape)?;
        let plan = CommPlan::Seq(vec![bottom.plan, top_plan(ops)]);
        return Ok(Resolution { plan, kind: ResolvedKind::BottomThenTop });
    }

    // Case 3 — BSR fallback (different DG unions or HSize, or an HDim
    // change with no split collective).
    if src.has_partial() || dst.has_partial() {
        return Err(Error::UnsupportedComm(format!(
            "transformation {} -> {} requires BSR but involves Partial values",
            src.describe(),
            dst.describe()
        )));
    }
    let mut loads = LoadTracker::default();
    let plan = plan_bsr(src, dst, shape, bw, opts, &mut loads)?;
    Ok(Resolution { plan: CommPlan::Bsr(plan), kind: ResolvedKind::Bsr })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::UniformBandwidth;
    use crate::hspmd::ds::{DUPLICATE, PARTIAL};
    use crate::hspmd::{DeviceGroup, DistStates, Subgroup};

    fn r(src: &Annotation, dst: &Annotation, shape: &[u64]) -> Resolution {
        resolve(src, dst, shape, &UniformBandwidth, BsrOptions::default()).unwrap()
    }

    fn spmd(ranks: Vec<u32>, ds: DistStates) -> Annotation {
        Annotation::spmd(DeviceGroup::new(ranks).unwrap(), ds).unwrap()
    }

    #[test]
    fn fig2_left_tp_allreduce() {
        // Fig 2 (left): Y partial over 2 TP workers ×2 DP groups → all-reduce.
        let ds_src = DistStates::new(&[(DUPLICATE, 2), (PARTIAL, 2)], &[-1, -2]).unwrap();
        let ds_dst = DistStates::new(&[(DUPLICATE, 4)], &[-1]).unwrap();
        let src = spmd(vec![0, 1, 2, 3], ds_src);
        let dst = spmd(vec![0, 1, 2, 3], ds_dst);
        let res = r(&src, &dst, &[8, 8]);
        // dup2*partial2 -> dup4 is a single relabel PARTIAL->DUP (merging) —
        // that merge isn't a single_transition, so it resolves via... AR with
        // groups along PARTIAL is the right answer; check we at least get a
        // legal plan (AR or BSR is rejected due to partial => must be AR).
        assert!(matches!(res.kind, ResolvedKind::AllReduce | ResolvedKind::MixedBottom), "{:?}", res.kind);
    }

    #[test]
    fn hetero_dp_grad_sync_splitar() {
        // Two subgroups with different TP degrees holding partial grads
        // (hdim=-2) → replicated grads (hdim=-1): SplitAllReduce (§4.2-I).
        let g0 = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![2]).unwrap(), DistStates::trivial()).unwrap();
        let src = Annotation::new(vec![g0.clone(), g1.clone()], PARTIAL).unwrap();
        let dst = Annotation::new(vec![g0, g1], DUPLICATE).unwrap();
        let res = r(&src, &dst, &[16]);
        assert_eq!(res.kind, ResolvedKind::SplitAllReduce);
    }

    #[test]
    fn bottom_then_top_combined() {
        // Fig 7: DS unions differ AND hdim changes: subgroup 0 must first
        // reduce-scatter its bottom partial, then SplitAR across subgroups.
        let g0s = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::partial(2)).unwrap();
        let g1s = Subgroup::new(DeviceGroup::new(vec![2, 3]).unwrap(), DistStates::split(0, 2)).unwrap();
        let src = Annotation::new(vec![g0s, g1s], PARTIAL).unwrap();
        let g0d = Subgroup::new(DeviceGroup::new(vec![0, 1]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g1d = Subgroup::new(DeviceGroup::new(vec![2, 3]).unwrap(), DistStates::split(0, 2)).unwrap();
        let dst = Annotation::new(vec![g0d, g1d], DUPLICATE).unwrap();
        let res = r(&src, &dst, &[8]);
        assert_eq!(res.kind, ResolvedKind::BottomThenTop);
        match res.plan {
            CommPlan::Seq(phases) => assert_eq!(phases.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn hsize_change_falls_to_bsr() {
        let one = spmd(vec![0, 1], DistStates::split(0, 2));
        let g0 = Subgroup::new(DeviceGroup::new(vec![2]).unwrap(), DistStates::trivial()).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![3]).unwrap(), DistStates::trivial()).unwrap();
        let two = Annotation::new(vec![g0, g1], 0).unwrap();
        let res = r(&one, &two, &[8]);
        assert_eq!(res.kind, ResolvedKind::Bsr);
    }

    #[test]
    fn hsize_change_with_partial_is_unsupported() {
        let one = spmd(vec![0, 1], DistStates::partial(2));
        let g0 = Subgroup::new(DeviceGroup::new(vec![2]).unwrap(), DistStates::trivial()).unwrap();
        let g1 = Subgroup::new(DeviceGroup::new(vec![3]).unwrap(), DistStates::trivial()).unwrap();
        let two = Annotation::new(vec![g0, g1], 0).unwrap();
        assert!(resolve(&one, &two, &[8], &UniformBandwidth, BsrOptions::default()).is_err());
    }

    #[test]
    fn identity_roundtrip() {
        let a = spmd(vec![0, 1, 2, 3], DistStates::split(0, 4));
        let res = r(&a, &a.clone(), &[8]);
        assert_eq!(res.kind, ResolvedKind::Identity);
        assert_eq!(res.plan.elems_on_wire(), 0);
    }

    #[test]
    fn mixed_bottom_kinds_labelled() {
        // subgroup 0: identity; subgroup 1: resplit (BSR) → MixedBottom? —
        // identity entries are filtered, single non-identity kind remains.
        let g0 = Subgroup::new(DeviceGroup::new(vec![0]).unwrap(), DistStates::trivial()).unwrap();
        let g1s = Subgroup::new(DeviceGroup::new(vec![1, 2]).unwrap(), DistStates::split(0, 2)).unwrap();
        let g1d = Subgroup::new(DeviceGroup::new(vec![1, 2]).unwrap(), DistStates::split(1, 2)).unwrap();
        let src = Annotation::new(vec![g0.clone(), g1s], 0).unwrap();
        let dst = Annotation::new(vec![g0, g1d], 0).unwrap();
        let res = r(&src, &dst, &[8, 4]);
        assert_eq!(res.kind, ResolvedKind::Bsr);
    }

    #[test]
    fn wire_volume_is_conserved_under_planner_options() {
        // §8/Table 2: heuristics change the *distribution*, not the total.
        let src = spmd(vec![0, 1, 2, 3], DistStates::split(0, 4));
        let dst = spmd(vec![4, 5], DistStates::split(1, 2));
        let a = resolve(&src, &dst, &[8, 8], &UniformBandwidth, BsrOptions { heuristics: true })
            .unwrap();
        let b = resolve(&src, &dst, &[8, 8], &UniformBandwidth, BsrOptions { heuristics: false })
            .unwrap();
        assert_eq!(a.plan.elems_on_wire(), b.plan.elems_on_wire());
    }
}
