//! §4 — Hierarchical communication resolution.
//!
//! Given a pair of HSPMD annotations (source sharding → destination
//! sharding) over a concrete tensor shape, derive the communication plan
//! that realizes the transformation:
//!
//! * **bottom tier** (§4.1) — same `HSize`/`HDim`: each sharding subgroup
//!   resolves independently to Identity / Send-Recv / AllReduce /
//!   ReduceScatter / AllGather / BSR ([`bottom`]);
//! * **top tier** (§4.2) — same `HSize` and DG union, different `HDim`:
//!   SplitAllReduce / SplitReduceScatter / SplitAllGather over the finest-
//!   grained slices, optionally preceded by a bottom-tier DS-alignment pass
//!   (Fig 7) ([`top`]);
//! * **fallback** (§4.3) — batched-send-receive with the paper's three
//!   sender-selection heuristics ([`bsr`]), and the §6.2 multi-tensor
//!   *fused* BSR used by graph switching ([`fused`]).

pub mod bottom;
pub mod bsr;
pub mod fused;
pub mod plan;
pub mod resolve;
pub mod top;

pub use bsr::{Bandwidth, BsrOptions, BsrPlan, Transfer, UniformBandwidth};
pub use fused::{plan_transition, FusedBsrPlan, FusedMessage, TensorMove};
pub use plan::{CollKind, CollectiveOp, CommPlan, ResolvedKind};
pub use resolve::{resolve, Resolution};
