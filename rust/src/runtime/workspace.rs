//! Kernel-level execution plan: per-rank workspace arenas, prepacked
//! weight panels, and fused block/head/embed drivers (DESIGN.md §12).
//!
//! PR 7's compiled tapes removed dispatch-layer allocation; this module
//! removes the *kernel*-layer allocation that remained. Every intermediate
//! a transformer block or the head produces (normed activations, q/k/v,
//! attention output, MLP pre-activations, all parameter gradients) becomes
//! a slice carved out of a [`KernelWorkspace`] — one grow-only arena per
//! device, sized at compile time from the `ShapeClass` (the
//! `WorkspacePlan` frozen into the `CompiledProgram`) and reused across
//! micro-batches and steps. Parameters are read through a [`PanelCache`]:
//! contiguous panels keyed by interned param `KeyId`, packed lazily on
//! first use and *invalidated* (marked stale, storage retained) on every
//! `OptimStep`, so the steady state repacks in place and never allocates.
//!
//! The drivers ([`block_fwd_ws`], [`block_bwd_ws`], [`head_step_ws`])
//! call only the `_into` kernels and fused epilogues of
//! [`native`](super::native), in exactly the oracle kernels' operation
//! order — bit-identical outputs, fewer launches, zero kernel bytes.

use super::native::{self, counters};
use super::ManifestConfig;
use crate::{Error, Result};

/// Frozen geometry of one transformer-block invocation on one device:
/// micro-batch shape `[b, s]` (flattened to `n = b·s` rows) and the
/// TP-local widths (`hl`/`fl`/`nh` are the per-shard slices of
/// hidden/ffn/heads). `v` carries the vocab for the head/embed drivers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockDims {
    /// Flattened rows (`b · s`).
    pub n: usize,
    /// Sequences in the micro-batch.
    pub b: usize,
    /// Sequence length.
    pub s: usize,
    /// Hidden size.
    pub h: usize,
    /// TP-local attention width (`hidden / tp`).
    pub hl: usize,
    /// TP-local FFN width (`ffn / tp`).
    pub fl: usize,
    /// TP-local head count (`heads / tp`).
    pub nh: usize,
    /// Per-head dim (`hidden / heads`).
    pub hd: usize,
    /// Vocabulary (head/embed drivers only).
    pub v: usize,
}

impl BlockDims {
    /// Dims for a `[n_seqs, seq_len]` micro-batch at TP degree `tp`.
    /// Caller guarantees divisibility (the compiler gates fusion on it).
    pub fn new(cfg: &ManifestConfig, tp: usize, n_seqs: usize, seq_len: usize) -> BlockDims {
        let h = cfg.hidden;
        BlockDims {
            n: n_seqs * seq_len,
            b: n_seqs,
            s: seq_len,
            h,
            hl: h / tp,
            fl: cfg.ffn / tp,
            nh: cfg.heads / tp,
            hd: h / cfg.heads,
            v: cfg.vocab,
        }
    }

    /// Floats of the forward intermediates shared with the backward
    /// recompute: xn1, q, k, v, att, lse, xn2, a, hh.
    fn parts_floats(&self) -> usize {
        2 * self.n * self.h          // xn1, xn2
            + 4 * self.n * self.hl   // q, k, v, att
            + self.n * self.nh       // lse
            + 2 * self.n * self.fl   // a, hh
    }

    /// Scratch floats [`block_fwd_ws`] carves (parts + att_out).
    pub fn fwd_scratch_floats(&self) -> usize {
        self.parts_floats() + self.n * self.h
    }

    /// Total forward reservation: the block output buffer + scratch.
    pub fn fwd_ws_floats(&self) -> usize {
        self.n * self.h + self.fwd_scratch_floats()
    }

    /// Scratch floats [`block_bwd_ws`] carves (recomputed parts + every
    /// backward intermediate and parameter gradient).
    pub fn bwd_scratch_floats(&self) -> usize {
        let (n, h, hl, fl) = (self.n, self.h, self.hl, self.fl);
        self.parts_floats()
            + n * fl            // da
            + 6 * n * h         // dxn2, dx_mlp, dxn1, dxn1_k, dxn1_v, dx_att
            + 2 * h             // dg1, dg2
            + 4 * h * hl        // dwq, dwk, dwv, dwo
            + 2 * h * fl        // dw1, dw2
            + 4 * n * hl        // datt, dq, dk, dv
    }

    /// Total backward reservation: the dx output buffer + scratch.
    pub fn bwd_ws_floats(&self) -> usize {
        self.n * self.h + self.bwd_scratch_floats()
    }

    /// Scratch floats [`head_step_ws`] carves: xn, logits, dlogits,
    /// dwout, dxn, dgf.
    pub fn head_ws_floats(&self) -> usize {
        2 * self.n * self.h + 2 * self.n * self.v + self.h * self.v + self.h
    }

    /// Scratch floats the fused embed backward carves (the `[v, h]`
    /// gradient accumulator).
    pub fn embed_bwd_ws_floats(&self) -> usize {
        self.v * self.h
    }
}

/// Compile-time per-device workspace sizing: the max over a device's
/// fused ops of their float reservations (DESIGN.md §12 sizing rule).
/// Frozen into the `CompiledProgram`; the executor's arena grows each
/// device's [`KernelWorkspace`] to this once per program install.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WorkspacePlan {
    /// Max float reservation per mesh device id.
    pub per_device_floats: Vec<usize>,
}

impl WorkspacePlan {
    /// Fold one fused op's reservation on `dev` into the plan.
    pub fn note(&mut self, dev: usize, floats: usize) {
        if self.per_device_floats.len() <= dev {
            self.per_device_floats.resize(dev + 1, 0);
        }
        let e = &mut self.per_device_floats[dev];
        *e = (*e).max(floats);
    }

    /// The frozen reservation for `dev` (0 when no fused op runs there).
    pub fn floats_for(&self, dev: usize) -> usize {
        self.per_device_floats.get(dev).copied().unwrap_or(0)
    }
}

/// Per-device kernel arena: one grow-only flat `f32` buffer that every
/// fused call on the device carves its intermediates out of. Growing
/// happens once at program install (and never on the warm path).
#[derive(Default)]
pub struct KernelWorkspace {
    buf: Vec<f32>,
}

impl KernelWorkspace {
    /// Grow (never shrink) to at least `floats`.
    pub fn ensure(&mut self, floats: usize) {
        if self.buf.len() < floats {
            self.buf.resize(floats, 0.0);
        }
    }

    /// A `floats`-long mutable window (grows if needed — a no-op warm).
    pub fn slice(&mut self, floats: usize) -> &mut [f32] {
        self.ensure(floats);
        &mut self.buf[..floats]
    }

    /// Read back the arena prefix (e.g. an output carved at offset 0).
    pub fn data(&self) -> &[f32] {
        &self.buf
    }

    /// Current capacity in floats.
    pub fn capacity_floats(&self) -> usize {
        self.buf.len()
    }
}

/// Sequentially split a workspace into disjoint `&mut` regions without
/// allocating (repeated `split_at_mut` through `mem::take`).
pub struct Carver<'a> {
    rest: &'a mut [f32],
    used: usize,
}

impl<'a> Carver<'a> {
    /// Carve from `buf` front to back.
    pub fn new(buf: &'a mut [f32]) -> Carver<'a> {
        Carver { rest: buf, used: 0 }
    }

    /// The next `n` floats as an independent `&mut` slice.
    pub fn take(&mut self, n: usize) -> &'a mut [f32] {
        let buf = std::mem::take(&mut self.rest);
        let (head, tail) = buf.split_at_mut(n);
        self.rest = tail;
        self.used += n;
        head
    }

    /// Floats carved so far (sizing-rule cross-check).
    pub fn used(&self) -> usize {
        self.used
    }
}

/// One prepacked parameter panel: a contiguous f32 copy of the weight,
/// GEMM-ready. `stale` marks it for in-place repacking after an
/// `OptimStep` mutated the source parameter.
struct Panel {
    data: Vec<f32>,
    stale: bool,
}

/// Prepacked-weight panel cache, keyed by interned param `KeyId` (dense
/// per-program indices, so lookup is an array access — no hashing, no
/// string keys). Populated lazily on first GEMM touching the param;
/// `invalidate` (on `OptimStep`/strategy switch) marks every panel stale
/// without dropping storage, so steady-state repacks are `copy_from_slice`
/// into the retained buffer — zero allocation.
#[derive(Default)]
pub struct PanelCache {
    panels: Vec<Option<Panel>>,
    /// Lookups served from a fresh panel.
    pub hits: u64,
    /// First-touch packs (allocate).
    pub misses: u64,
    /// In-place repacks of a stale panel (no allocation).
    pub repacks: u64,
}

impl PanelCache {
    /// Pack (or refresh) panel `id` from the f32 weight `src`.
    pub fn ensure(&mut self, id: usize, src: &[f32]) {
        if self.panels.len() <= id {
            self.panels.resize_with(id + 1, || None);
        }
        match &mut self.panels[id] {
            Some(p) if !p.stale => self.hits += 1,
            Some(p) if p.data.len() == src.len() => {
                p.data.copy_from_slice(src);
                p.stale = false;
                self.repacks += 1;
            }
            slot => {
                *slot = Some(Panel { data: src.to_vec(), stale: false });
                self.misses += 1;
            }
        }
    }

    /// Pack (or refresh) panel `id` by dequantizing a bf16 weight — the
    /// persistent dequant panel for [`native::matmul_bf16_panel_into`].
    pub fn ensure_bf16(&mut self, id: usize, src: &[u16]) {
        if self.panels.len() <= id {
            self.panels.resize_with(id + 1, || None);
        }
        let dequant_into = |dst: &mut [f32]| {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d = native::bf16_to_f32(s);
            }
        };
        match &mut self.panels[id] {
            Some(p) if !p.stale => self.hits += 1,
            Some(p) if p.data.len() == src.len() => {
                dequant_into(&mut p.data);
                p.stale = false;
                self.repacks += 1;
            }
            slot => {
                let mut data = vec![0.0f32; src.len()];
                dequant_into(&mut data);
                *slot = Some(Panel { data, stale: false });
                self.misses += 1;
            }
        }
    }

    /// The packed panel for `id` (must have been `ensure`d).
    pub fn get(&self, id: usize) -> &[f32] {
        self.panels[id].as_ref().map(|p| p.data.as_slice()).expect("panel not packed")
    }

    /// Mark every panel stale (parameters changed: `OptimStep`, strategy
    /// switch). Storage is retained for in-place repacking.
    pub fn invalidate(&mut self) {
        for p in self.panels.iter_mut().flatten() {
            p.stale = true;
        }
    }

    /// Drop all panels (program identity changed: `KeyId`s re-interned).
    pub fn clear(&mut self) {
        self.panels.clear();
    }
}

/// Parameter gradients of one fused block backward, as workspace slices
/// in `BLOCK_PARAMS` order (g1, wq, wk, wv, wo, g2, w1, w2) — exactly the
/// order of the compiled op's `gkeys`.
pub struct BwdGrads<'w> {
    /// d(gain 1) `[h]`.
    pub dg1: &'w [f32],
    /// d(wq) `[h, hl]`.
    pub dwq: &'w [f32],
    /// d(wk) `[h, hl]`.
    pub dwk: &'w [f32],
    /// d(wv) `[h, hl]`.
    pub dwv: &'w [f32],
    /// d(wo) `[hl, h]`.
    pub dwo: &'w [f32],
    /// d(gain 2) `[h]`.
    pub dg2: &'w [f32],
    /// d(w1) `[h, fl]`.
    pub dw1: &'w [f32],
    /// d(w2) `[fl, h]`.
    pub dw2: &'w [f32],
}

impl<'w> BwdGrads<'w> {
    /// Gradient slice by `BLOCK_PARAMS` index.
    pub fn by_index(&self, i: usize) -> &'w [f32] {
        match i {
            0 => self.dg1,
            1 => self.dwq,
            2 => self.dwk,
            3 => self.dwv,
            4 => self.dwo,
            5 => self.dg2,
            6 => self.dw1,
            7 => self.dw2,
            _ => unreachable!("BLOCK_PARAMS has 8 entries"),
        }
    }
}

/// Shape of the `BLOCK_PARAMS[i]` gradient at `d` (cold-path tensor
/// creation only — the warm path accumulates in place, no shapes needed).
pub fn grad_shape(d: &BlockDims, i: usize) -> Vec<usize> {
    match i {
        0 | 5 => vec![d.h],
        1 | 2 | 3 => vec![d.h, d.hl],
        4 => vec![d.hl, d.h],
        6 => vec![d.h, d.fl],
        7 => vec![d.fl, d.h],
        _ => unreachable!("BLOCK_PARAMS has 8 entries"),
    }
}

/// Recompute the shared forward intermediates into carved slices.
/// Returns `(xn1, q, k, v, att, lse, xn2, a, hh)`.
#[allow(clippy::type_complexity)]
fn forward_parts<'a>(
    d: &BlockDims,
    p: &[&[f32]; 8],
    x: &[f32],
    c: &mut Carver<'a>,
) -> (
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
    &'a mut [f32],
) {
    let (n, h, hl, fl) = (d.n, d.h, d.hl, d.fl);
    let xn1 = c.take(n * h);
    let q = c.take(n * hl);
    let k = c.take(n * hl);
    let v = c.take(n * hl);
    let att = c.take(n * hl);
    let lse = c.take(n * d.nh);
    let xn2 = c.take(n * h);
    let a = c.take(n * fl);
    let hh = c.take(n * fl);

    native::rmsnorm_into(x, p[0], n, h, xn1);
    native::matmul_into(xn1, p[1], n, h, hl, q);
    native::matmul_into(xn1, p[2], n, h, hl, k);
    native::matmul_into(xn1, p[3], n, h, hl, v);
    native::attention_into(q, k, v, d.b, d.s, d.nh, d.hd, att, lse);
    native::rmsnorm_into(x, p[5], n, h, xn2);
    // fused GEMM+GeLU: `a` (pre-activation, kept for dGeLU) and `hh` in
    // one launch, same accumulation order as matmul-then-map
    native::matmul_bias_gelu_into(xn2, p[6], None, n, h, fl, a, hh);
    (xn1, q, k, v, att, lse, xn2, a, hh)
}

/// Fused block forward: the partial block output into `out [n, h]`, all
/// intermediates carved from `ws` (≥ [`BlockDims::fwd_scratch_floats`]).
/// Bit-identical to the unfused `block_fwd_tp{d}` artifact in 9 kernel
/// launches (vs 11 unfused), zero allocations.
pub fn block_fwd_ws(d: &BlockDims, p: &[&[f32]; 8], x: &[f32], out: &mut [f32], ws: &mut [f32]) {
    let (n, h, hl, fl) = (d.n, d.h, d.hl, d.fl);
    debug_assert_eq!(out.len(), n * h);
    let mut c = Carver::new(ws);
    let (_, _, _, _, att, _, _, _, hh) = forward_parts(d, p, x, &mut c);
    let att_out = c.take(n * h);
    native::matmul_into(att, p[4], n, hl, h, att_out);
    // fused GEMM+residual: out = hh@w2 + att_out — f32 addition commutes,
    // so this equals the oracle's att_out + mlp_out bit for bit
    native::matmul_residual_into(hh, p[7], n, fl, h, att_out, out);
    debug_assert_eq!(c.used(), d.fwd_scratch_floats());
}

/// Fused block backward: upstream `dy [n, h]` → `dx` (written to the
/// caller's buffer) and the eight parameter gradients as slices of `ws`
/// (≥ [`BlockDims::bwd_scratch_floats`]). Bit-identical to the unfused
/// `block_bwd_tp{d}` artifact in 24 launches (vs 26), zero allocations.
pub fn block_bwd_ws<'w>(
    d: &BlockDims,
    p: &[&[f32]; 8],
    x: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    ws: &'w mut [f32],
) -> BwdGrads<'w> {
    let (n, h, hl, fl) = (d.n, d.h, d.hl, d.fl);
    debug_assert_eq!(dx.len(), n * h);
    let mut c = Carver::new(ws);
    let (xn1, q, k, v, att, lse, xn2, a, hh) = forward_parts(d, p, x, &mut c);

    // ---- MLP branch
    let dw2 = c.take(fl * h);
    let da = c.take(n * fl);
    let dw1 = c.take(h * fl);
    let dxn2 = c.take(n * h);
    let dx_mlp = c.take(n * h);
    let dg2 = c.take(h);
    native::matmul_tn_into(hh, dy, n, fl, h, dw2);
    // fused NT-GEMM+dGeLU: da = (dy@w2ᵀ) ⊙ gelu'(a) in one launch
    native::matmul_nt_dgelu_into(dy, p[7], a, n, h, fl, da);
    native::matmul_tn_into(xn2, da, n, h, fl, dw1);
    native::matmul_nt_into(da, p[6], n, fl, h, dxn2);
    native::rmsnorm_bwd_into(x, p[5], dxn2, n, h, dx_mlp, dg2);

    // ---- attention branch
    let dwo = c.take(hl * h);
    let datt = c.take(n * hl);
    let dq = c.take(n * hl);
    let dk = c.take(n * hl);
    let dv = c.take(n * hl);
    let dwq = c.take(h * hl);
    let dwk = c.take(h * hl);
    let dwv = c.take(h * hl);
    let dxn1 = c.take(n * h);
    let dxn1_k = c.take(n * h);
    let dxn1_v = c.take(n * h);
    let dx_att = c.take(n * h);
    let dg1 = c.take(h);
    native::matmul_tn_into(att, dy, n, hl, h, dwo);
    native::matmul_nt_into(dy, p[4], n, h, hl, datt);
    native::attention_bwd_into(q, k, v, lse, att, datt, d.b, d.s, d.nh, d.hd, dq, dk, dv);
    native::matmul_tn_into(xn1, dq, n, h, hl, dwq);
    native::matmul_tn_into(xn1, dk, n, h, hl, dwk);
    native::matmul_tn_into(xn1, dv, n, h, hl, dwv);
    native::matmul_nt_into(dq, p[1], n, hl, h, dxn1);
    native::matmul_nt_into(dk, p[2], n, hl, h, dxn1_k);
    native::matmul_nt_into(dv, p[3], n, hl, h, dxn1_v);
    counters::launch(); // dxn1 merge pass (oracle associativity: k+v first)
    for i in 0..dxn1.len() {
        dxn1[i] += dxn1_k[i] + dxn1_v[i];
    }
    native::rmsnorm_bwd_into(x, p[0], dxn1, n, h, dx_att, dg1);

    counters::launch(); // dx residual-merge pass
    for i in 0..dx.len() {
        dx[i] = dx_att[i] + dx_mlp[i];
    }
    debug_assert_eq!(c.used(), d.bwd_scratch_floats());
    BwdGrads { dg1, dwq, dwk, dwv, dwo, dg2, dw1, dw2 }
}

/// Head gradients as workspace slices (mutable: the executor scales them
/// by the token weight in place before accumulating).
pub struct HeadGrads<'w> {
    /// d(final gain) `[h]`.
    pub dgf: &'w mut [f32],
    /// d(wout) `[h, v]`.
    pub dwout: &'w mut [f32],
}

/// Fused head step: rmsnorm → logits → masked softmax-CE →
/// dwout/dxn/rmsnorm-bwd, every intermediate carved from `ws`
/// (≥ [`BlockDims::head_ws_floats`]); `dx [n, h]` is written to the
/// caller's buffer. Bit-identical to the `head_step` artifact — same
/// masking (`-1` targets drop out of loss/grad and the mean), same error
/// cases (all-masked, target ≥ vocab).
#[allow(clippy::too_many_arguments)]
pub fn head_step_ws<'w>(
    n: usize,
    h: usize,
    v: usize,
    gf: &[f32],
    wout: &[f32],
    x: &[f32],
    targets: &[i32],
    dx: &mut [f32],
    ws: &'w mut [f32],
) -> Result<(f32, HeadGrads<'w>)> {
    debug_assert_eq!(targets.len(), n);
    debug_assert_eq!(dx.len(), n * h);
    let count = targets.iter().filter(|&&tgt| tgt >= 0).count();
    if count == 0 {
        return Err(Error::Runtime("head_step: every target is masked".into()));
    }
    let mut c = Carver::new(ws);
    let xn = c.take(n * h);
    let logits = c.take(n * v);
    let dlogits = c.take(n * v);
    let dwout = c.take(h * v);
    let dxn = c.take(n * h);
    let dgf = c.take(h);

    native::rmsnorm_into(x, gf, n, h, xn);
    native::matmul_into(xn, wout, n, h, v, logits);
    counters::launch(); // softmax-CE / dlogits pass
    dlogits.fill(0.0); // masked rows must stay zero in a reused buffer
    let mut loss = 0.0f32;
    for r in 0..n {
        if targets[r] < 0 {
            continue; // masked: dlogits row stays zero
        }
        let row = &logits[r * v..(r + 1) * v];
        let tgt = targets[r] as usize;
        if tgt >= v {
            return Err(Error::Runtime(format!("head_step: target {tgt} ≥ vocab {v}")));
        }
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &l in row {
            denom += (l - max).exp();
        }
        let logz = max + denom.ln();
        loss += logz - row[tgt];
        let drow = &mut dlogits[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (row[j] - max).exp() / denom;
            drow[j] = p / count as f32;
        }
        drow[tgt] -= 1.0 / count as f32;
    }
    loss /= count as f32;

    native::matmul_tn_into(xn, dlogits, n, h, v, dwout);
    native::matmul_nt_into(dlogits, wout, n, v, h, dxn);
    native::rmsnorm_bwd_into(x, gf, dxn, n, h, dx, dgf);
    Ok((loss, HeadGrads { dgf, dwout }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostTensor;
    use crate::testutil::Rng;

    fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.f32_signed() * scale).collect()
    }

    fn block_params(
        rng: &mut Rng,
        cfg: &ManifestConfig,
        tp: usize,
    ) -> Vec<HostTensor> {
        let (h, f) = (cfg.hidden, cfg.ffn);
        let (hl, fl) = (h / tp, f / tp);
        vec![
            HostTensor::f32(vec![h], randvec(rng, h, 1.0)).unwrap(),
            HostTensor::f32(vec![h, hl], randvec(rng, h * hl, 0.05)).unwrap(),
            HostTensor::f32(vec![h, hl], randvec(rng, h * hl, 0.05)).unwrap(),
            HostTensor::f32(vec![h, hl], randvec(rng, h * hl, 0.05)).unwrap(),
            HostTensor::f32(vec![hl, h], randvec(rng, hl * h, 0.05)).unwrap(),
            HostTensor::f32(vec![h], randvec(rng, h, 1.0)).unwrap(),
            HostTensor::f32(vec![h, fl], randvec(rng, h * fl, 0.05)).unwrap(),
            HostTensor::f32(vec![fl, h], randvec(rng, fl * h, 0.05)).unwrap(),
        ]
    }

    /// The fused block drivers vs the unfused `block_fwd_tp{d}` /
    /// `block_bwd_tp{d}` oracle artifacts, bit for bit, across ragged
    /// `[n_seqs, seq_len]` shapes and TP degrees — on dirty workspaces.
    #[test]
    fn fused_block_drivers_bit_identical_to_oracle_artifacts() {
        let cfg = native::tiny_config();
        for (case, &(tp, b, s)) in
            [(0usize, (1usize, 2usize, 16usize)), (1, (2, 1, 5)), (2, (4, 3, 7)), (3, (2, 2, 33))]
                .iter()
                .map(|(c, t)| (*c, t))
        {
            let mut rng = Rng::new(901 + case as u64);
            let d = BlockDims::new(&cfg, tp, b, s);
            let params = block_params(&mut rng, &cfg, tp);
            let x = HostTensor::f32(vec![b, s, cfg.hidden], randvec(&mut rng, d.n * d.h, 0.5))
                .unwrap();
            let dy = HostTensor::f32(vec![b, s, cfg.hidden], randvec(&mut rng, d.n * d.h, 1.0))
                .unwrap();

            let mut fwd_in: Vec<&HostTensor> = params.iter().collect();
            fwd_in.push(&x);
            let y_oracle = native::call(&cfg, &format!("block_fwd_tp{tp}"), &fwd_in).unwrap();

            let pslices: [&[f32]; 8] =
                std::array::from_fn(|i| params[i].as_f32().unwrap());
            let mut ws = vec![777.0f32; d.fwd_scratch_floats()];
            let mut y = vec![777.0f32; d.n * d.h];
            block_fwd_ws(&d, &pslices, x.as_f32().unwrap(), &mut y, &mut ws);
            assert_eq!(
                y,
                y_oracle[0].as_f32().unwrap(),
                "case {case}: fused fwd vs oracle"
            );

            let mut bwd_in = fwd_in.clone();
            bwd_in.push(&dy);
            let g_oracle = native::call(&cfg, &format!("block_bwd_tp{tp}"), &bwd_in).unwrap();

            let mut ws = vec![777.0f32; d.bwd_scratch_floats()];
            let mut dx = vec![777.0f32; d.n * d.h];
            let grads = block_bwd_ws(
                &d,
                &pslices,
                x.as_f32().unwrap(),
                dy.as_f32().unwrap(),
                &mut dx,
                &mut ws,
            );
            assert_eq!(dx, g_oracle[0].as_f32().unwrap(), "case {case}: fused dx");
            for i in 0..8 {
                assert_eq!(
                    grads.by_index(i),
                    g_oracle[i + 1].as_f32().unwrap(),
                    "case {case}: fused grad {i}"
                );
                assert_eq!(
                    grad_shape(&d, i),
                    g_oracle[i + 1].shape,
                    "case {case}: grad shape {i}"
                );
            }
        }
    }

    /// The fused head driver vs the `head_step` oracle artifact across
    /// masked ragged shapes — bit-identical loss/dx/dgf/dwout, same
    /// error cases.
    #[test]
    fn fused_head_driver_matches_oracle_including_masking() {
        let cfg = ManifestConfig { hidden: 12, vocab: 19, ..native::tiny_config() };
        let (h, v) = (cfg.hidden, cfg.vocab);
        for (case, &(b, s)) in
            [(0usize, (1usize, 4usize)), (1, (2, 7)), (2, (3, 5))].iter().map(|(c, t)| (*c, t))
        {
            let mut rng = Rng::new(71 + case as u64);
            let n = b * s;
            let gf = HostTensor::f32(vec![h], randvec(&mut rng, h, 1.0)).unwrap();
            let wout = HostTensor::f32(vec![h, v], randvec(&mut rng, h * v, 0.3)).unwrap();
            let x = HostTensor::f32(vec![b, s, h], randvec(&mut rng, n * h, 0.5)).unwrap();
            // ragged masking: pad the tail of each sequence
            let tgts: Vec<i32> = (0..n)
                .map(|i| if i % s >= s - case % s.max(1) { -1 } else { ((i * 3) % v) as i32 })
                .collect();
            let any_real = tgts.iter().any(|&t| t >= 0);
            if !any_real {
                continue;
            }
            let t = HostTensor::i32(vec![b, s], tgts.clone()).unwrap();
            let oracle = native::call(&cfg, "head_step", &[&gf, &wout, &x, &t]).unwrap();

            let d = BlockDims { n, b, s, h, hl: h, fl: h, nh: 1, hd: h, v };
            let mut ws = vec![777.0f32; d.head_ws_floats()];
            let mut dx = vec![777.0f32; n * h];
            let (loss, grads) = head_step_ws(
                n,
                h,
                v,
                gf.as_f32().unwrap(),
                wout.as_f32().unwrap(),
                x.as_f32().unwrap(),
                &tgts,
                &mut dx,
                &mut ws,
            )
            .unwrap();
            assert_eq!(loss, oracle[0].as_f32().unwrap()[0], "case {case}: loss bits");
            assert_eq!(dx, oracle[1].as_f32().unwrap(), "case {case}: dx bits");
            assert_eq!(&*grads.dgf, oracle[2].as_f32().unwrap(), "case {case}: dgf bits");
            assert_eq!(&*grads.dwout, oracle[3].as_f32().unwrap(), "case {case}: dwout bits");
        }

        // error cases mirror the oracle: all-masked and out-of-vocab
        let mut rng = Rng::new(5);
        let n = 3;
        let gf = randvec(&mut rng, h, 1.0);
        let wout = randvec(&mut rng, h * v, 0.3);
        let x = randvec(&mut rng, n * h, 0.5);
        let mut ws = vec![0.0f32; 2 * n * h + 2 * n * v + h * v + h];
        let mut dx = vec![0.0f32; n * h];
        assert!(head_step_ws(n, h, v, &gf, &wout, &x, &[-1, -1, -1], &mut dx, &mut ws).is_err());
        assert!(
            head_step_ws(n, h, v, &gf, &wout, &x, &[1, v as i32, 2], &mut dx, &mut ws).is_err()
        );
    }

    /// Panel-cache lifecycle: miss → hit → invalidate → in-place repack
    /// (storage retained, no reallocation) → clear.
    #[test]
    fn panel_cache_repacks_in_place_after_invalidation() {
        let mut pc = PanelCache::default();
        let w1: Vec<f32> = (0..64).map(|i| i as f32).collect();
        pc.ensure(3, &w1);
        assert_eq!((pc.misses, pc.hits, pc.repacks), (1, 0, 0));
        assert_eq!(pc.get(3), &w1[..]);
        let ptr_before = pc.get(3).as_ptr();

        pc.ensure(3, &w1);
        assert_eq!((pc.misses, pc.hits, pc.repacks), (1, 1, 0));

        pc.invalidate();
        let w2: Vec<f32> = (0..64).map(|i| -(i as f32)).collect();
        pc.ensure(3, &w2);
        assert_eq!((pc.misses, pc.hits, pc.repacks), (1, 1, 1));
        assert_eq!(pc.get(3), &w2[..]);
        assert_eq!(pc.get(3).as_ptr(), ptr_before, "repack must reuse the buffer");

        // bf16 panels: dequantized copies, same lifecycle
        let w16: Vec<u16> = w1.iter().map(|&x| native::f32_to_bf16(x)).collect();
        pc.ensure_bf16(7, &w16);
        let want: Vec<f32> = w16.iter().map(|&x| native::bf16_to_f32(x)).collect();
        assert_eq!(pc.get(7), &want[..]);
        let mut out = vec![0.0f32; 64];
        let a16: Vec<u16> = (0..8).map(|i| native::f32_to_bf16(i as f32 * 0.5)).collect();
        native::matmul_bf16_panel_into(&a16, pc.get(7), 1, 8, 8, &mut out[..8]);
        let dense = native::matmul_bf16(&a16, &w16[..64], 1, 8, 8);
        assert_eq!(&out[..8], &dense[..], "panel GEMM vs dense bf16 GEMM");

        pc.clear();
        pc.ensure(3, &w1);
        assert_eq!(pc.misses, 2, "clear drops storage; next ensure re-allocates");
    }

    /// The compile-time sizing rule is exact: the drivers carve precisely
    /// the advertised float counts (an exactly-sized buffer suffices).
    #[test]
    fn workspace_sizing_rule_is_exact() {
        let cfg = native::tiny_config();
        let d = BlockDims::new(&cfg, 2, 2, 9);
        let mut rng = Rng::new(17);
        let params = block_params(&mut rng, &cfg, 2);
        let pslices: [&[f32]; 8] = std::array::from_fn(|i| params[i].as_f32().unwrap());
        let x = randvec(&mut rng, d.n * d.h, 0.5);
        let dy = randvec(&mut rng, d.n * d.h, 1.0);

        let mut ws = vec![0.0f32; d.fwd_scratch_floats()]; // exact, no slack
        let mut y = vec![0.0f32; d.n * d.h];
        block_fwd_ws(&d, &pslices, &x, &mut y, &mut ws);

        let mut ws = vec![0.0f32; d.bwd_scratch_floats()]; // exact, no slack
        let mut dx = vec![0.0f32; d.n * d.h];
        let _ = block_bwd_ws(&d, &pslices, &x, &dy, &mut dx, &mut ws);

        // plan folding takes the max per device
        let mut plan = WorkspacePlan::default();
        plan.note(1, 100);
        plan.note(1, 50);
        plan.note(3, 10);
        assert_eq!(plan.floats_for(1), 100);
        assert_eq!(plan.floats_for(3), 10);
        assert_eq!(plan.floats_for(0), 0);
        assert_eq!(plan.floats_for(9), 0);

        // the workspace grows once and stays
        let mut ksw = KernelWorkspace::default();
        ksw.ensure(64);
        let p0 = ksw.slice(64).as_ptr();
        assert_eq!(ksw.slice(32).as_ptr(), p0, "smaller slice reuses the buffer");
        assert_eq!(ksw.capacity_floats(), 64);
    }
}
