//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the L3 hot path. Python never runs here.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile`. Host tensors are
//! [`HostTensor`] (shape + f32/i32 payload); conversion to/from
//! `xla::Literal` happens at the call boundary.

pub mod native;
pub mod tensor;
pub mod workspace;

pub use tensor::HostTensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Symbolic batch-rows dimension in an artifact input shape: bound per
/// call, consistently across every input (the §5.5 symbolic-shape axis at
/// runtime scale). Only the native backend registers symbolic dims — AOT
/// PJRT artifacts are compiled at fixed shapes, so their manifests carry
/// literals and validation stays exact.
pub const DIM_BATCH: i64 = -1;
/// Symbolic sequence-length dimension (see [`DIM_BATCH`]).
pub const DIM_SEQ: i64 = -2;

/// Artifact metadata parsed from `manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// File name relative to the artifact dir.
    pub file: String,
    /// Input shapes + dtypes (`"f32"`/`"i32"`). Non-negative dims are
    /// literal; [`DIM_BATCH`]/[`DIM_SEQ`] are symbolic and bind per call.
    pub inputs: Vec<(Vec<i64>, String)>,
    /// Number of outputs in the result tuple.
    pub outputs: usize,
}

/// Model configuration recorded by the exporter.
#[derive(Clone, Copy, Debug, Default)]
pub struct ManifestConfig {
    /// Transformer layers.
    pub layers: u32,
    /// Hidden size.
    pub hidden: usize,
    /// FFN inner size.
    pub ffn: usize,
    /// Attention heads.
    pub heads: usize,
    /// Vocabulary.
    pub vocab: usize,
    /// Compiled micro-batch size.
    pub batch: usize,
    /// Compiled sequence length.
    pub seq: usize,
}

/// Execution backend behind the artifact registry.
enum Backend {
    /// PJRT client + lazily-compiled HLO-text executables.
    Pjrt {
        /// The PJRT CPU client.
        client: xla::PjRtClient,
        /// Artifact directory.
        dir: PathBuf,
        /// Compiled-executable cache.
        executables: std::sync::Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    },
    /// Pure-Rust reference implementations ([`native`]).
    Native,
}

/// The artifact registry: named model-compute entry points executed either
/// through PJRT (AOT HLO artifacts) or the in-crate [`native`] reference
/// backend. Both expose the same artifact names, shapes, and semantics, so
/// the engine is backend-agnostic.
pub struct Runtime {
    backend: Backend,
    metas: HashMap<String, ArtifactMeta>,
    /// Exporter-recorded model config.
    pub config: ManifestConfig,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let (metas, config) = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| Error::Runtime(format!("PJRT CPU client: {e}")))?;
        Ok(Runtime {
            backend: Backend::Pjrt {
                client,
                dir,
                executables: std::sync::Mutex::new(HashMap::new()),
            },
            metas,
            config,
        })
    }

    /// A runtime served entirely by the native Rust reference backend
    /// (no artifacts, no PJRT): same artifact names/shapes as the exporter.
    pub fn native(config: ManifestConfig) -> Runtime {
        Runtime { backend: Backend::Native, metas: native::artifact_metas(&config), config }
    }

    /// Open `dir` if it holds a manifest, otherwise fall back to the
    /// native backend at the tiny-48 configuration. This is the engine's
    /// default entry point: real AOT artifacts when present, reference
    /// numerics everywhere else.
    pub fn open_or_native(dir: impl AsRef<Path>) -> Result<Runtime> {
        if dir.as_ref().join("manifest.json").exists() {
            Runtime::open(dir)
        } else {
            eprintln!(
                "note: no manifest at `{}` — using the native reference backend \
                 (tiny-48 model); run `make artifacts` for the compiled model",
                dir.as_ref().display()
            );
            Ok(Runtime::native(native::tiny_config()))
        }
    }

    /// True when running on the native reference backend.
    pub fn is_native(&self) -> bool {
        matches!(self.backend, Backend::Native)
    }

    /// True if the registry lists artifact `name`.
    pub fn metas_has(&self, name: &str) -> bool {
        self.metas.contains_key(name)
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.metas.keys().cloned().collect();
        v.sort();
        v
    }

    /// Metadata of one artifact.
    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta> {
        self.metas
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact `{name}`")))
    }

    fn compiled(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let (client, dir, executables) = match &self.backend {
            Backend::Pjrt { client, dir, executables } => (client, dir, executables),
            Backend::Native => {
                return Err(Error::Runtime(format!(
                    "artifact `{name}` is served natively; nothing to compile"
                )))
            }
        };
        {
            let cache = executables.lock().unwrap();
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
        }
        let meta = self.meta(name)?;
        let path = dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::Runtime(format!("compile {name}: {e}")))?;
        let exe = std::sync::Arc::new(exe);
        executables.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (engine startup). No-op on the
    /// native backend.
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        if self.is_native() {
            return Ok(());
        }
        for n in names {
            self.compiled(n)?;
        }
        Ok(())
    }

    /// Execute an artifact on host tensors; returns the flattened output
    /// tuple. Validates arity + shapes against the manifest.
    pub fn call(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.call_refs(name, &refs)
    }

    /// [`Runtime::call`] over borrowed tensors — the engine's hot path
    /// (§Perf L3): parameters stay in the device stores; no per-call clone.
    ///
    /// Literal manifest dims must match exactly; symbolic dims
    /// ([`DIM_BATCH`]/[`DIM_SEQ`], native backend only) bind to the first
    /// actual extent seen and must stay consistent across the call's
    /// inputs — this is what lets one registered artifact execute ragged
    /// `[n_seqs, seq_len]` micro-batches of any shape.
    pub fn call_refs(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let meta = self.meta(name)?.clone();
        if inputs.len() != meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            )));
        }
        let (mut bound_b, mut bound_s): (Option<usize>, Option<usize>) = (None, None);
        for (i, (t, (shape, dtype))) in inputs.iter().zip(meta.inputs.iter()).enumerate() {
            let dims_ok = t.shape.len() == shape.len()
                && shape.iter().zip(t.shape.iter()).all(|(&md, &ad)| match md {
                    DIM_BATCH => ad > 0 && *bound_b.get_or_insert(ad) == ad,
                    DIM_SEQ => ad > 0 && *bound_s.get_or_insert(ad) == ad,
                    lit => lit >= 0 && lit as usize == ad,
                });
            if !dims_ok || t.dtype_str() != dtype {
                return Err(Error::Runtime(format!(
                    "{name}: input {i} is {:?}/{} but manifest wants {:?}/{} \
                     (-1/-2 are symbolic batch/seq dims, bound per call)",
                    t.shape,
                    t.dtype_str(),
                    shape,
                    dtype
                )));
            }
        }
        if self.is_native() {
            let out = native::call(&self.config, name, inputs)?;
            if out.len() != meta.outputs {
                return Err(Error::Runtime(format!(
                    "{name}: native backend produced {} outputs, manifest promises {}",
                    out.len(),
                    meta.outputs
                )));
            }
            return Ok(out);
        }
        let exe = self.compiled(name)?;
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| Error::Runtime(format!("execute {name}: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| Error::Runtime(format!("fetch {name}: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| Error::Runtime(format!("untuple {name}: {e}")))?;
        if parts.len() != meta.outputs {
            return Err(Error::Runtime(format!(
                "{name}: manifest promises {} outputs, got {}",
                meta.outputs,
                parts.len()
            )));
        }
        parts.into_iter().map(HostTensor::from_literal).collect()
    }
}

/// Minimal JSON parsing for the exporter's manifest (no serde offline).
/// The format is fully under our control (`aot.py`), so a hand-rolled
/// recursive-descent parser over the small grammar is safe and dependency-
/// free.
fn parse_manifest(text: &str) -> Result<(HashMap<String, ArtifactMeta>, ManifestConfig)> {
    let v = json::parse(text)?;
    let cfg = v.get("config").ok_or_else(|| Error::Runtime("manifest: no config".into()))?;
    let num = |k: &str| -> Result<usize> {
        cfg.get(k)
            .and_then(|x| x.as_f64())
            .map(|f| f as usize)
            .ok_or_else(|| Error::Runtime(format!("manifest config missing `{k}`")))
    };
    let config = ManifestConfig {
        layers: num("layers")? as u32,
        hidden: num("hidden")?,
        ffn: num("ffn")?,
        heads: num("heads")?,
        vocab: num("vocab")?,
        batch: num("batch")?,
        seq: num("seq")?,
    };
    let arts = v
        .get("artifacts")
        .and_then(|a| a.as_object())
        .ok_or_else(|| Error::Runtime("manifest: no artifacts".into()))?;
    let mut metas = HashMap::new();
    for (name, m) in arts {
        let file = m
            .get("file")
            .and_then(|f| f.as_str())
            .ok_or_else(|| Error::Runtime(format!("artifact {name}: no file")))?
            .to_string();
        let outputs = m
            .get("outputs")
            .and_then(|o| o.as_f64())
            .ok_or_else(|| Error::Runtime(format!("artifact {name}: no outputs")))?
            as usize;
        let mut inputs = vec![];
        for inp in m
            .get("inputs")
            .and_then(|i| i.as_array())
            .ok_or_else(|| Error::Runtime(format!("artifact {name}: no inputs")))?
        {
            let pair = inp.as_array().ok_or_else(|| Error::Runtime("bad input entry".into()))?;
            // manifests describe fixed-shape AOT artifacts: every dim must
            // be a literal. Symbolic dims (DIM_BATCH/DIM_SEQ) are reserved
            // for the native registry — a negative manifest dim must not
            // silently enable ragged binding against a compiled executable.
            let mut dims: Vec<i64> = Vec::new();
            for d in pair[0]
                .as_array()
                .ok_or_else(|| Error::Runtime("bad input dims".into()))?
            {
                let d = d.as_f64().unwrap_or(-1.0) as i64;
                if d < 0 {
                    return Err(Error::Runtime(format!(
                        "artifact {name}: negative input dim {d} (AOT shapes are literal)"
                    )));
                }
                dims.push(d);
            }
            let dtype =
                pair[1].as_str().ok_or_else(|| Error::Runtime("bad input dtype".into()))?;
            inputs.push((dims, dtype.to_string()));
        }
        metas.insert(name.clone(), ArtifactMeta { file, inputs, outputs });
    }
    Ok((metas, config))
}

/// Tiny JSON value + parser (objects, arrays, strings, numbers, bools).
pub mod json {
    use crate::{Error, Result};
    use std::collections::BTreeMap;

    /// A parsed JSON value.
    #[derive(Clone, Debug, PartialEq)]
    pub enum Value {
        /// null
        Null,
        /// boolean
        Bool(bool),
        /// number (f64)
        Num(f64),
        /// string
        Str(String),
        /// array
        Arr(Vec<Value>),
        /// object (ordered)
        Obj(BTreeMap<String, Value>),
    }

    impl Value {
        /// Object field lookup.
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(m) => m.get(key),
                _ => None,
            }
        }
        /// As f64.
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
        /// As str.
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
        /// As array.
        pub fn as_array(&self) -> Option<&Vec<Value>> {
            match self {
                Value::Arr(a) => Some(a),
                _ => None,
            }
        }
        /// As object.
        pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
            match self {
                Value::Obj(o) => Some(o),
                _ => None,
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Runtime("json: trailing garbage".into()));
        }
        Ok(v)
    }

    struct Parser<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl<'a> Parser<'a> {
        fn ws(&mut self) {
            while self.i < self.b.len() && (self.b[self.i] as char).is_whitespace() {
                self.i += 1;
            }
        }
        fn peek(&mut self) -> Result<u8> {
            self.ws();
            self.b
                .get(self.i)
                .copied()
                .ok_or_else(|| Error::Runtime("json: unexpected end".into()))
        }
        fn eat(&mut self, c: u8) -> Result<()> {
            if self.peek()? != c {
                return Err(Error::Runtime(format!(
                    "json: expected `{}` at {}",
                    c as char, self.i
                )));
            }
            self.i += 1;
            Ok(())
        }
        fn value(&mut self) -> Result<Value> {
            match self.peek()? {
                b'{' => self.object(),
                b'[' => self.array(),
                b'"' => Ok(Value::Str(self.string()?)),
                b't' => self.lit("true", Value::Bool(true)),
                b'f' => self.lit("false", Value::Bool(false)),
                b'n' => self.lit("null", Value::Null),
                _ => self.number(),
            }
        }
        fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
            if self.b[self.i..].starts_with(s.as_bytes()) {
                self.i += s.len();
                Ok(v)
            } else {
                Err(Error::Runtime(format!("json: bad literal at {}", self.i)))
            }
        }
        fn number(&mut self) -> Result<Value> {
            let start = self.i;
            while self.i < self.b.len()
                && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            }
            std::str::from_utf8(&self.b[start..self.i])
                .ok()
                .and_then(|s| s.parse().ok())
                .map(Value::Num)
                .ok_or_else(|| Error::Runtime(format!("json: bad number at {start}")))
        }
        fn string(&mut self) -> Result<String> {
            self.eat(b'"')?;
            let mut out = String::new();
            while self.i < self.b.len() {
                let c = self.b[self.i];
                self.i += 1;
                match c {
                    b'"' => return Ok(out),
                    b'\\' => {
                        let e = self.b.get(self.i).copied().unwrap_or(b'"');
                        self.i += 1;
                        out.push(match e {
                            b'n' => '\n',
                            b't' => '\t',
                            b'r' => '\r',
                            other => other as char,
                        });
                    }
                    other => out.push(other as char),
                }
            }
            Err(Error::Runtime("json: unterminated string".into()))
        }
        fn array(&mut self) -> Result<Value> {
            self.eat(b'[')?;
            let mut out = vec![];
            if self.peek()? == b']' {
                self.i += 1;
                return Ok(Value::Arr(out));
            }
            loop {
                out.push(self.value()?);
                match self.peek()? {
                    b',' => self.i += 1,
                    b']' => {
                        self.i += 1;
                        return Ok(Value::Arr(out));
                    }
                    c => {
                        return Err(Error::Runtime(format!(
                            "json: expected , or ] got `{}`",
                            c as char
                        )))
                    }
                }
            }
        }
        fn object(&mut self) -> Result<Value> {
            self.eat(b'{')?;
            let mut out = BTreeMap::new();
            if self.peek()? == b'}' {
                self.i += 1;
                return Ok(Value::Obj(out));
            }
            loop {
                self.ws();
                let key = self.string()?;
                self.eat(b':')?;
                out.insert(key, self.value()?);
                match self.peek()? {
                    b',' => self.i += 1,
                    b'}' => {
                        self.i += 1;
                        return Ok(Value::Obj(out));
                    }
                    c => {
                        return Err(Error::Runtime(format!(
                            "json: expected , or }} got `{}`",
                            c as char
                        )))
                    }
                }
            }
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn parses_manifest_like_json() {
            let v = parse(
                r#"{"config": {"layers": 8}, "artifacts": {"a": {"inputs": [[[2, 3], "f32"]], "outputs": 1, "file": "a.hlo.txt"}}}"#,
            )
            .unwrap();
            assert_eq!(v.get("config").unwrap().get("layers").unwrap().as_f64(), Some(8.0));
            let a = v.get("artifacts").unwrap().get("a").unwrap();
            assert_eq!(a.get("outputs").unwrap().as_f64(), Some(1.0));
            let inp = a.get("inputs").unwrap().as_array().unwrap();
            assert_eq!(inp[0].as_array().unwrap()[1].as_str(), Some("f32"));
        }

        #[test]
        fn rejects_garbage() {
            assert!(parse("{").is_err());
            assert!(parse("{}x").is_err());
        }

        #[test]
        fn parses_nested_arrays_and_escapes() {
            let v = parse(r#"{"s": "a\nb", "a": [1, [2, 3], true, null]}"#).unwrap();
            assert_eq!(v.get("s").unwrap().as_str(), Some("a\nb"));
            assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing_roundtrip() {
        let text = r#"{
            "config": {"layers": 8, "hidden": 768, "ffn": 3072, "heads": 12,
                       "vocab": 32000, "batch": 2, "seq": 128, "tp_degrees": [1, 2, 4]},
            "artifacts": {
                "embed_fwd": {"file": "embed_fwd.hlo.txt",
                               "inputs": [[[32000, 768], "f32"], [[2, 128], "i32"]],
                               "outputs": 1}
            }
        }"#;
        let (metas, cfg) = parse_manifest(text).unwrap();
        assert_eq!(cfg.layers, 8);
        assert_eq!(cfg.seq, 128);
        let m = &metas["embed_fwd"];
        assert_eq!(m.outputs, 1);
        assert_eq!(m.inputs[0].0, vec![32000, 768]);
        assert_eq!(m.inputs[1].1, "i32");
    }

    #[test]
    fn open_missing_dir_is_friendly() {
        let err = match Runtime::open("/nonexistent-artifacts") {
            Err(e) => e,
            Ok(_) => panic!("open should fail"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn open_or_native_falls_back() {
        let rt = Runtime::open_or_native("/nonexistent-artifacts").unwrap();
        assert!(rt.is_native());
        assert!(rt.metas_has("head_step"));
        assert!(rt.metas_has("block_fwd_tp4"));
        assert_eq!(rt.config.layers, native::tiny_config().layers);
    }

    #[test]
    fn native_call_validates_shapes_and_runs() {
        let rt = Runtime::native(native::tiny_config());
        let cfg = rt.config;
        let emb = HostTensor::zeros(vec![cfg.vocab, cfg.hidden]);
        let tok =
            HostTensor::i32(vec![cfg.batch, cfg.seq], vec![1; cfg.batch * cfg.seq]).unwrap();
        let out = rt.call("embed_fwd", &[emb.clone(), tok.clone()]).unwrap();
        assert_eq!(out[0].shape, vec![cfg.batch, cfg.seq, cfg.hidden]);
        // wrong shape is rejected by the manifest check
        let bad = HostTensor::zeros(vec![cfg.vocab, cfg.hidden + 1]);
        assert!(rt.call("embed_fwd", &[bad, tok]).is_err());
    }

    #[test]
    fn native_call_binds_symbolic_shapes_per_call() {
        // the native registry's batch/seq dims are symbolic: a ragged
        // [1, 5] micro-batch runs through the same artifact entry as the
        // compiled [2, 16] shape, while inconsistent bindings across one
        // call's inputs are rejected
        let rt = Runtime::native(native::tiny_config());
        let cfg = rt.config;
        let emb = HostTensor::zeros(vec![cfg.vocab, cfg.hidden]);
        let tok = HostTensor::i32(vec![1, 5], vec![1; 5]).unwrap();
        let out = rt.call("embed_fwd", &[emb, tok]).unwrap();
        assert_eq!(out[0].shape, vec![1, 5, cfg.hidden]);

        let gf = HostTensor::zeros(vec![cfg.hidden]);
        let wout = HostTensor::zeros(vec![cfg.hidden, cfg.vocab]);
        let x = HostTensor::zeros(vec![1, 5, cfg.hidden]);
        let bad_tgt = HostTensor::i32(vec![1, 4], vec![1; 4]).unwrap();
        assert!(
            rt.call("head_step", &[gf.clone(), wout.clone(), x.clone(), bad_tgt]).is_err(),
            "seq bound to 5 by x must reject a [1, 4] target"
        );
        let tgt = HostTensor::i32(vec![1, 5], vec![1; 5]).unwrap();
        let out = rt.call("head_step", &[gf, wout, x, tgt]).unwrap();
        assert_eq!(out[1].shape, vec![1, 5, cfg.hidden]);
    }
}
