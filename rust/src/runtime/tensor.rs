//! Host-side tensors: the engine's in-memory representation, convertible
//! to/from `xla::Literal` at the PJRT call boundary.

use crate::{Error, Result};

/// Payload storage.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// f32 buffer.
    F32(Vec<f32>),
    /// i32 buffer (token ids).
    I32(Vec<i32>),
}

/// A dense row-major host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Data.
    pub data: Payload,
}

impl HostTensor {
    /// f32 tensor from data.
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Runtime(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(HostTensor { shape, data: Payload::F32(data) })
    }

    /// i32 tensor from data.
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<HostTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Runtime(format!(
                "shape {shape:?} wants {n} elements, got {}",
                data.len()
            )));
        }
        Ok(HostTensor { shape, data: Payload::I32(data) })
    }

    /// Zero-filled f32 tensor.
    pub fn zeros(shape: Vec<usize>) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: Payload::F32(vec![0.0; n]) }
    }

    /// Element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// dtype tag matching the exporter manifest.
    pub fn dtype_str(&self) -> &'static str {
        match self.data {
            Payload::F32(_) => "f32",
            Payload::I32(_) => "i32",
        }
    }

    /// Borrow f32 data.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::Runtime("tensor is not f32".into())),
        }
    }

    /// Mutable f32 data.
    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            Payload::F32(v) => Ok(v),
            _ => Err(Error::Runtime("tensor is not f32".into())),
        }
    }

    /// Borrow i32 data.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Payload::I32(v) => Ok(v),
            _ => Err(Error::Runtime("tensor is not i32".into())),
        }
    }

    /// In-place elementwise add (the engine's AllReduce combiner).
    pub fn add_assign(&mut self, other: &HostTensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Runtime(format!(
                "add_assign shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        let b = other.as_f32()?;
        for (x, y) in self.as_f32_mut()?.iter_mut().zip(b.iter()) {
            *x += y;
        }
        Ok(())
    }

    /// In-place scale.
    pub fn scale(&mut self, s: f32) -> Result<()> {
        for x in self.as_f32_mut()? {
            *x *= s;
        }
        Ok(())
    }

    /// Convert to an XLA literal. Single-copy path (§Perf L3): allocate the
    /// literal at its final shape and `copy_raw_from` — the original
    /// `vec1().reshape()` route copied every payload twice.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape.clone();
        match &self.data {
            Payload::F32(v) => {
                let mut lit =
                    xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
                lit.copy_raw_from(v.as_slice())
                    .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?;
                Ok(lit)
            }
            Payload::I32(v) => {
                let mut lit =
                    xla::Literal::create_from_shape(xla::PrimitiveType::S32, &dims);
                lit.copy_raw_from(v.as_slice())
                    .map_err(|e| Error::Runtime(format!("literal copy: {e}")))?;
                Ok(lit)
            }
        }
    }

    /// Convert from an XLA literal (f32/f64/i32/i64/scalars supported).
    pub fn from_literal(lit: xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| Error::Runtime(format!("literal shape: {e}")))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| Error::Runtime(e.to_string()))?;
                HostTensor::f32(dims, v)
            }
            xla::ElementType::S32 => {
                let v = lit.to_vec::<i32>().map_err(|e| Error::Runtime(e.to_string()))?;
                HostTensor::i32(dims, v)
            }
            other => Err(Error::Runtime(format!("unsupported literal type {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_arity() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn add_assign_and_scale() {
        let mut a = HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = HostTensor::f32(vec![4], vec![10.0; 4]).unwrap();
        a.add_assign(&b).unwrap();
        a.scale(0.5).unwrap();
        assert_eq!(a.as_f32().unwrap(), &[5.5, 6.0, 6.5, 7.0]);
    }

    #[test]
    fn add_assign_rejects_shape_mismatch() {
        let mut a = HostTensor::zeros(vec![2]);
        let b = HostTensor::zeros(vec![3]);
        assert!(a.add_assign(&b).is_err());
    }

    #[test]
    fn dtype_tags() {
        assert_eq!(HostTensor::zeros(vec![1]).dtype_str(), "f32");
        assert_eq!(HostTensor::i32(vec![1], vec![7]).unwrap().dtype_str(), "i32");
    }
}
