//! Native reference backend: pure-Rust implementations of the L2 model
//! artifacts (`python/compile/model.py`), used when no AOT/PJRT artifact
//! directory is available.
//!
//! The math mirrors the JAX reference kernels exactly (`kernels/ref.py`):
//! RMSNorm with `eps = 1e-6`, scaled-dot-product causal attention, tanh-
//! approximate GeLU, mean softmax cross-entropy. Backward passes are the
//! hand-derived VJPs of the same forward, so the engine's distributed
//! numerics can be validated end-to-end (TP/PP/DP invariance, graph
//! switching transparency, hetero-TP) with no Python and no XLA on the
//! training path.
//!
//! TP contract (identical to the AOT artifacts): `block_fwd_tp{d}` takes
//! Megatron-sharded parameters (`wq/wk/wv/w1` column-split, `wo/w2`
//! row-split, gains replicated) and returns a *partial* block output; the
//! engine all-reduces over the TP group and adds the residual.
//! `block_bwd_tp{d}` returns `(dx_partial, dparams_shard)`.

use std::collections::HashMap;

use super::{ArtifactMeta, ManifestConfig, DIM_BATCH, DIM_SEQ};
use crate::runtime::HostTensor;
use crate::{Error, Result};

/// RMSNorm numerical floor (matches `kernels/ref.py`).
const RMS_EPS: f32 = 1e-6;

/// The model configuration the native backend serves when no artifact
/// manifest overrides it: an 8-layer "tiny-48" transformer small enough
/// that full training steps are fast in debug builds, with every dimension
/// divisible by the supported TP degrees {1, 2, 4}.
pub fn tiny_config() -> ManifestConfig {
    ManifestConfig { layers: 8, hidden: 48, ffn: 96, heads: 4, vocab: 512, batch: 2, seq: 16 }
}

/// TP degrees the native backend provides block artifacts for.
pub const TP_DEGREES: [usize; 3] = [1, 2, 4];

/// Process-wide kernel accounting (DESIGN.md §12): every public kernel
/// entry counts one *launch*, and every output/scratch `Vec` a kernel
/// allocates counts its bytes. The fused workspace path
/// (`runtime/workspace.rs`) calls only the `_into` variants, so a warm
/// fused step adds zero to [`counters::KERNEL_BYTES`] — the engine
/// snapshots the deltas per step into `StepStats`.
pub mod counters {
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Kernel invocations (GEMMs, norms, attention, and the elementwise
    /// map passes of the unfused block path — each is one launch).
    pub static KERNEL_LAUNCHES: AtomicU64 = AtomicU64::new(0);
    /// Bytes heap-allocated *inside* kernels (outputs and scratch; the
    /// store/comm layers are accounted elsewhere).
    pub static KERNEL_BYTES: AtomicU64 = AtomicU64::new(0);

    /// Count one kernel launch.
    #[inline]
    pub fn launch() {
        KERNEL_LAUNCHES.fetch_add(1, Ordering::Relaxed);
    }

    /// Count a kernel-internal allocation of `n` f32 elements.
    #[inline]
    pub fn alloc_f32(n: usize) {
        KERNEL_BYTES.fetch_add((n * 4) as u64, Ordering::Relaxed);
    }

    /// `(launches, bytes)` so far — subtract two snapshots for a
    /// per-step delta.
    pub fn snapshot() -> (u64, u64) {
        (KERNEL_LAUNCHES.load(Ordering::Relaxed), KERNEL_BYTES.load(Ordering::Relaxed))
    }
}

/// Grow-only thread-local scratch for the non-fused kernel path: the
/// bf16 GEMM's dequant block and the flash-attention row accumulator
/// used to be fresh `Vec`s *per call* — callers in loops paid an
/// allocation per invocation. Warm calls now allocate nothing.
mod scratch {
    use std::cell::RefCell;

    thread_local! {
        /// [`super::matmul_bf16`]'s per-k-block dequant panel.
        pub(super) static BBLK: RefCell<Vec<f32>> = RefCell::new(Vec::new());
        /// [`super::attention`]'s per-row output accumulator.
        pub(super) static ACC: RefCell<Vec<f32>> = RefCell::new(Vec::new());
    }
}

/// Build the artifact registry the native backend implements for `cfg`
/// (same names, input orders, and output arities as the AOT exporter).
/// Parameter dims are literal; the batch/seq dims are *symbolic*
/// ([`DIM_BATCH`]/[`DIM_SEQ`]) and bind per call, so one registered
/// artifact executes ragged `[n_seqs, seq_len]` micro-batches of any
/// shape — the §5.5 symbolic-shape machinery at native-backend scale.
pub fn artifact_metas(cfg: &ManifestConfig) -> HashMap<String, ArtifactMeta> {
    let (h, f, v) = (cfg.hidden as i64, cfg.ffn as i64, cfg.vocab as i64);
    let (b, s) = (DIM_BATCH, DIM_SEQ);
    let f32s = |shape: Vec<i64>| (shape, "f32".to_string());
    let i32s = |shape: Vec<i64>| (shape, "i32".to_string());
    let mut metas = HashMap::new();
    metas.insert(
        "embed_fwd".to_string(),
        ArtifactMeta {
            file: "<native>".into(),
            inputs: vec![f32s(vec![v, h]), i32s(vec![b, s])],
            outputs: 1,
        },
    );
    metas.insert(
        "embed_bwd".to_string(),
        ArtifactMeta {
            file: "<native>".into(),
            inputs: vec![i32s(vec![b, s]), f32s(vec![b, s, h])],
            outputs: 1,
        },
    );
    metas.insert(
        "head_step".to_string(),
        ArtifactMeta {
            file: "<native>".into(),
            inputs: vec![f32s(vec![h]), f32s(vec![h, v]), f32s(vec![b, s, h]), i32s(vec![b, s])],
            outputs: 4,
        },
    );
    for tp in TP_DEGREES {
        if cfg.heads % tp != 0 || cfg.ffn % tp != 0 || cfg.hidden % tp != 0 {
            continue;
        }
        let tp_i = tp as i64;
        let block_inputs = vec![
            f32s(vec![h]),            // g1
            f32s(vec![h, h / tp_i]),  // wq
            f32s(vec![h, h / tp_i]),  // wk
            f32s(vec![h, h / tp_i]),  // wv
            f32s(vec![h / tp_i, h]),  // wo
            f32s(vec![h]),            // g2
            f32s(vec![h, f / tp_i]),  // w1
            f32s(vec![f / tp_i, h]),  // w2
        ];
        let mut fwd_inputs = block_inputs.clone();
        fwd_inputs.push(f32s(vec![b, s, h]));
        metas.insert(
            format!("block_fwd_tp{tp}"),
            ArtifactMeta { file: "<native>".into(), inputs: fwd_inputs, outputs: 1 },
        );
        let mut bwd_inputs = block_inputs;
        bwd_inputs.push(f32s(vec![b, s, h]));
        bwd_inputs.push(f32s(vec![b, s, h]));
        metas.insert(
            format!("block_bwd_tp{tp}"),
            ArtifactMeta { file: "<native>".into(), inputs: bwd_inputs, outputs: 9 },
        );
    }
    metas
}

/// Dispatch a native artifact call. Input arity/shapes are validated by
/// `Runtime::call_refs` before this is reached.
pub fn call(cfg: &ManifestConfig, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    match name {
        "embed_fwd" => embed_fwd(cfg, inputs[0], inputs[1]).map(|t| vec![t]),
        "embed_bwd" => embed_bwd(cfg, inputs[0], inputs[1]).map(|t| vec![t]),
        "head_step" => head_step(cfg, inputs[0], inputs[1], inputs[2], inputs[3]),
        _ => {
            if let Some(tp) = name.strip_prefix("block_fwd_tp").and_then(|d| d.parse().ok()) {
                block_fwd(cfg, tp, inputs).map(|t| vec![t])
            } else if let Some(tp) = name.strip_prefix("block_bwd_tp").and_then(|d| d.parse().ok())
            {
                block_bwd(cfg, tp, inputs)
            } else {
                Err(Error::Runtime(format!("native backend: unknown artifact `{name}`")))
            }
        }
    }
}

// ---------------------------------------------------------------- helpers
//
// The three GEMM variants below are the native backend's hot path (the
// tiny-48 head alone is a 32×48×512 GEMM ×3 per micro-batch). They are
// blocked over the reduction dimension for cache reuse and dispatch to a
// runtime-detected AVX2 microkernel tier, with portable four-wide chunked
// kernels as the fallback (DESIGN.md §8 lays out the tier ladder) — the
// `hotpath_micro` bench rows guard the tiny-48 debug-mode step budget.
// Accumulation stays k-ordered in `matmul`/`matmul_tn` and the AVX2 tier
// multiplies then adds (never FMA), so results are bit-identical to the
// naive loops on every tier; `matmul_nt` uses independent lane
// accumulators (f32 reorder within each dot product, tolerance-tested).

/// Reduction-dimension cache block.
const KBLOCK: usize = 64;

/// `dst += s · src` over equal-length rows: the portable tier. Four-wide
/// `chunks_exact` lets the compiler drop per-element bounds checks
/// without `unsafe`.
#[inline(always)]
fn axpy_scalar(dst: &mut [f32], src: &[f32], s: f32) {
    assert_eq!(dst.len(), src.len());
    let mut d4 = dst.chunks_exact_mut(4);
    let mut a4 = src.chunks_exact(4);
    for (d, a) in (&mut d4).zip(&mut a4) {
        d[0] += s * a[0];
        d[1] += s * a[1];
        d[2] += s * a[2];
        d[3] += s * a[3];
    }
    for (d, a) in d4.into_remainder().iter_mut().zip(a4.remainder()) {
        *d += s * a;
    }
}

/// Four-way unrolled dot product: the portable tier.
#[inline(always)]
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut a4 = a.chunks_exact(4);
    let mut b4 = b.chunks_exact(4);
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for (x, y) in (&mut a4).zip(&mut b4) {
        s0 += x[0] * y[0];
        s1 += x[1] * y[1];
        s2 += x[2] * y[2];
        s3 += x[3] * y[3];
    }
    let mut acc = (s0 + s1) + (s2 + s3);
    for (x, y) in a4.remainder().iter().zip(b4.remainder()) {
        acc += x * y;
    }
    acc
}

/// AVX2 microkernel tier, dispatched at runtime (`std::arch` f32x8).
#[cfg(target_arch = "x86_64")]
mod simd {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
        _mm256_storeu_ps,
    };

    /// True when the running CPU has AVX2 (std caches the probe).
    #[inline(always)]
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// `dst += s · src`, eight lanes per step. Multiplies then adds —
    /// never FMA — so every element sees exactly the scalar tier's IEEE
    /// operations and the blocked GEMMs stay bit-identical to the naive
    /// reference loops.
    ///
    /// # Safety
    /// The CPU must support AVX2 (check [`available`] first).
    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy(dst: &mut [f32], src: &[f32], s: f32) {
        assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let sv = _mm256_set1_ps(s);
        let mut i = 0usize;
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let a = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, _mm256_mul_ps(sv, a)));
            i += 8;
        }
        while i < n {
            *dst.get_unchecked_mut(i) += s * *src.get_unchecked(i);
            i += 1;
        }
    }

    /// Eight-lane dot product (independent lane sums, reduced pairwise —
    /// an f32 reorder relative to the scalar tier, which only the
    /// tolerance-tested `matmul_nt` consumers observe).
    ///
    /// # Safety
    /// The CPU must support AVX2 (check [`available`] first).
    #[target_feature(enable = "avx2")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        assert_eq!(a.len(), b.len());
        let n = a.len();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(a.as_ptr().add(i));
            let y = _mm256_loadu_ps(b.as_ptr().add(i));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(x, y));
            i += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut sum = ((lanes[0] + lanes[4]) + (lanes[1] + lanes[5]))
            + ((lanes[2] + lanes[6]) + (lanes[3] + lanes[7]));
        while i < n {
            sum += *a.get_unchecked(i) * *b.get_unchecked(i);
            i += 1;
        }
        sum
    }
}

/// `dst += s · src`, dispatched to the AVX2 tier when the CPU has it.
/// Bit-identical across tiers (the AVX2 kernel never fuses multiply-add).
#[inline(always)]
fn axpy(dst: &mut [f32], src: &[f32], s: f32) {
    #[cfg(target_arch = "x86_64")]
    if simd::available() {
        // SAFETY: `available()` just confirmed AVX2 on this CPU.
        unsafe { simd::axpy(dst, src, s) };
        return;
    }
    axpy_scalar(dst, src, s)
}

/// Dot product, dispatched to the AVX2 tier when the CPU has it.
#[inline(always)]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if simd::available() {
        // SAFETY: `available()` just confirmed AVX2 on this CPU.
        return unsafe { simd::dot(a, b) };
    }
    dot_scalar(a, b)
}

/// `out[m,n] = a[m,k] @ b[k,n]` written into a caller-provided buffer
/// (row-major, k-ordered f32 accumulation, k-blocked). `out` is
/// zero-filled first, so a reused workspace slice gives exactly the
/// fresh-allocation result.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    counters::launch();
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for k0 in (0..k).step_by(KBLOCK) {
        let k1 = (k0 + KBLOCK).min(k);
        for i in 0..m {
            let row = &mut out[i * n..(i + 1) * n];
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate().take(k1).skip(k0) {
                if av == 0.0 {
                    continue;
                }
                axpy(row, &b[kk * n..(kk + 1) * n], av);
            }
        }
    }
}

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major, k-ordered f32 accumulation,
/// k-blocked). Public so the `hotpath_micro` bench can guard it.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    counters::alloc_f32(m * n);
    let mut out = vec![0.0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

/// `out[k,n] = a[m,k]ᵀ @ b[m,n]` into a caller-provided (zero-filled
/// here) buffer.
pub fn matmul_tn_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    counters::launch();
    debug_assert_eq!(out.len(), k * n);
    out.fill(0.0);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            axpy(&mut out[kk * n..(kk + 1) * n], brow, av);
        }
    }
}

/// `out[k,n] = a[m,k]ᵀ @ b[m,n]` (gradient w.r.t. a weight).
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    counters::alloc_f32(k * n);
    let mut out = vec![0.0f32; k * n];
    matmul_tn_into(a, b, m, k, n, &mut out);
    out
}

/// `out[m,k] = a[m,n] @ b[k,n]ᵀ` into a caller-provided buffer (every
/// element is overwritten).
pub fn matmul_nt_into(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    counters::launch();
    debug_assert_eq!(out.len(), m * k);
    for i in 0..m {
        let arow = &a[i * n..(i + 1) * n];
        let row = &mut out[i * k..(i + 1) * k];
        for (kk, r) in row.iter_mut().enumerate() {
            *r = dot(arow, &b[kk * n..(kk + 1) * n]);
        }
    }
}

/// `out[m,k] = a[m,n] @ b[k,n]ᵀ` (gradient w.r.t. a matmul input).
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    counters::alloc_f32(m * k);
    let mut out = vec![0.0f32; m * k];
    matmul_nt_into(a, b, m, n, k, &mut out);
    out
}

// --------------------------------------------------------- fused epilogues
//
// Each fused kernel is ONE launch replacing a GEMM plus the elementwise
// pass that always follows it in the transformer block, and is
// bit-identical to running the two unfused kernels: the epilogue runs
// *after* the full GEMM accumulation in the oracle's element order
// (f32 addition is commutative, so `gemm + residual` may be evaluated as
// residual-last; it is NOT associative, so the epilogue never folds into
// the k-loop accumulation).

/// GEMM + optional bias + tanh-GeLU: `pre = a@b (+ bias)`, `out = gelu(pre)`.
/// `pre` is kept (the block backward needs the pre-activation for dGeLU).
#[allow(clippy::too_many_arguments)]
pub fn matmul_bias_gelu_into(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    m: usize,
    k: usize,
    n: usize,
    pre: &mut [f32],
    out: &mut [f32],
) {
    counters::launch();
    debug_assert_eq!(pre.len(), m * n);
    debug_assert_eq!(out.len(), m * n);
    pre.fill(0.0);
    for k0 in (0..k).step_by(KBLOCK) {
        let k1 = (k0 + KBLOCK).min(k);
        for i in 0..m {
            let row = &mut pre[i * n..(i + 1) * n];
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &av) in arow.iter().enumerate().take(k1).skip(k0) {
                if av == 0.0 {
                    continue;
                }
                axpy(row, &b[kk * n..(kk + 1) * n], av);
            }
        }
    }
    if let Some(bias) = bias {
        debug_assert_eq!(bias.len(), n);
        for i in 0..m {
            let row = &mut pre[i * n..(i + 1) * n];
            for (p, &bv) in row.iter_mut().zip(bias) {
                *p += bv;
            }
        }
    }
    for (o, &p) in out.iter_mut().zip(pre.iter()) {
        *o = gelu(p);
    }
}

/// GEMM + residual axpy: `out = a@b + res` (GEMM accumulates into `out`,
/// then the residual is added — commutative with the oracle's
/// `res + gemm`, so bit-identical).
pub fn matmul_residual_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    res: &[f32],
    out: &mut [f32],
) {
    matmul_into(a, b, m, k, n, out);
    debug_assert_eq!(res.len(), out.len());
    for (o, &r) in out.iter_mut().zip(res) {
        *o += r;
    }
}

/// NT-GEMM + dGeLU epilogue: `out[i] = (dy @ w2ᵀ)[i] · gelu'(pre[i])` —
/// the MLP's `da` in one launch. Per element the dot completes before the
/// multiply, exactly the unfused `matmul_nt` + map order.
pub fn matmul_nt_dgelu_into(
    dy: &[f32],
    b: &[f32],
    pre: &[f32],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
) {
    counters::launch();
    debug_assert_eq!(out.len(), m * k);
    debug_assert_eq!(pre.len(), m * k);
    for i in 0..m {
        let arow = &dy[i * n..(i + 1) * n];
        let row = &mut out[i * k..(i + 1) * k];
        let prow = &pre[i * k..(i + 1) * k];
        for (kk, r) in row.iter_mut().enumerate() {
            *r = dot(arow, &b[kk * n..(kk + 1) * n]) * dgelu(prow[kk]);
        }
    }
}

// ------------------------------------------------------------- bf16 tier
//
// bf16-storage / f32-accumulate: operands live as bf16 (half the memory
// traffic of f32), every arithmetic op runs in f32. Off by default — the
// engine's differential-numerics oracles are all-f32 — but benched by
// `hotpath_micro` and property-tested to be *exactly* the f32 kernels on
// dequantized inputs (same k-ordered loop, same zero-skip).

/// Round-to-nearest-even f32 → bf16 (finite inputs; bf16 is the top half
/// of an f32, so this is a mantissa-rounding truncation).
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    (bits.wrapping_add(0x7fff + ((bits >> 16) & 1)) >> 16) as u16
}

/// bf16 → f32 (exact: widen the stored top half).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// `out[m,n] = a[m,k] @ b[k,n]` over **bf16-stored** operands with f32
/// accumulation. The k-blocked loop dequantizes each `b` block once and
/// then runs exactly [`matmul`]'s k-ordered accumulation (zero-skip
/// included), so the result is bit-identical to dequantizing both
/// operands up front and calling [`matmul`].
pub fn matmul_bf16(a: &[u16], b: &[u16], m: usize, k: usize, n: usize) -> Vec<f32> {
    counters::launch();
    counters::alloc_f32(m * n);
    let mut out = vec![0.0f32; m * n];
    // per-k-block dequant panel, hoisted to grow-only thread-local
    // scratch: callers in loops used to pay a KBLOCK·n allocation per call
    scratch::BBLK.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < KBLOCK * n {
            let need = KBLOCK * n;
            buf.resize(need, 0.0);
        }
        let bblk = &mut buf[..KBLOCK * n];
        for k0 in (0..k).step_by(KBLOCK) {
            let k1 = (k0 + KBLOCK).min(k);
            for (d, &sb) in bblk.iter_mut().zip(&b[k0 * n..k1 * n]) {
                *d = bf16_to_f32(sb);
            }
            for i in 0..m {
                let row = &mut out[i * n..(i + 1) * n];
                let arow = &a[i * k..(i + 1) * k];
                for (kk, &ab) in arow.iter().enumerate().take(k1).skip(k0) {
                    let av = bf16_to_f32(ab);
                    if av == 0.0 {
                        continue;
                    }
                    axpy(row, &bblk[(kk - k0) * n..(kk - k0 + 1) * n], av);
                }
            }
        }
    });
    out
}

/// bf16 GEMM against a **persistent dequant panel**: `panel` is the f32
/// dequantization of the bf16 `b` operand (see
/// `workspace::PanelCache::ensure_bf16`), prepared once and reused across
/// micro-batches/steps instead of re-dequantized per call. The k-ordered
/// zero-skip accumulation is exactly [`matmul_bf16`]'s, so on a panel
/// that equals `bf16_to_f32(b)` the result is bit-identical.
pub fn matmul_bf16_panel_into(
    a: &[u16],
    panel: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    counters::launch();
    debug_assert_eq!(panel.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for k0 in (0..k).step_by(KBLOCK) {
        let k1 = (k0 + KBLOCK).min(k);
        for i in 0..m {
            let row = &mut out[i * n..(i + 1) * n];
            let arow = &a[i * k..(i + 1) * k];
            for (kk, &ab) in arow.iter().enumerate().take(k1).skip(k0) {
                let av = bf16_to_f32(ab);
                if av == 0.0 {
                    continue;
                }
                axpy(row, &panel[kk * n..(kk + 1) * n], av);
            }
        }
    }
}

/// RMSNorm over rows of `x [n, h]` with gain `g [h]`, into a
/// caller-provided buffer (every element overwritten).
pub fn rmsnorm_into(x: &[f32], g: &[f32], n: usize, h: usize, out: &mut [f32]) {
    counters::launch();
    debug_assert_eq!(out.len(), n * h);
    for r in 0..n {
        let row = &x[r * h..(r + 1) * h];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        let orow = &mut out[r * h..(r + 1) * h];
        for i in 0..h {
            orow[i] = row[i] * inv * g[i];
        }
    }
}

/// RMSNorm over rows of `x [n, h]` with gain `g [h]`.
fn rmsnorm(x: &[f32], g: &[f32], n: usize, h: usize) -> Vec<f32> {
    counters::alloc_f32(n * h);
    let mut out = vec![0.0f32; n * h];
    rmsnorm_into(x, g, n, h, &mut out);
    out
}

/// VJP of [`rmsnorm`] into caller-provided `dx [n,h]` / `dg [h]` buffers
/// (`dg` accumulates over rows, so it is zero-filled here).
pub fn rmsnorm_bwd_into(
    x: &[f32],
    g: &[f32],
    dxn: &[f32],
    n: usize,
    h: usize,
    dx: &mut [f32],
    dg: &mut [f32],
) {
    counters::launch();
    debug_assert_eq!(dx.len(), n * h);
    debug_assert_eq!(dg.len(), h);
    dg.fill(0.0);
    for r in 0..n {
        let row = &x[r * h..(r + 1) * h];
        let drow = &dxn[r * h..(r + 1) * h];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / h as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        let mut dot = 0.0f32;
        for i in 0..h {
            dg[i] += drow[i] * row[i] * inv;
            dot += drow[i] * g[i] * row[i];
        }
        let coef = inv * inv * inv * dot / h as f32;
        let orow = &mut dx[r * h..(r + 1) * h];
        for i in 0..h {
            orow[i] = inv * g[i] * drow[i] - row[i] * coef;
        }
    }
}

/// VJP of [`rmsnorm`]: given upstream `dxn`, returns `(dx, dg)`.
///
/// With `r = (mean(x²)+eps)^{-1/2}`:
/// `dg_i = Σ_rows dxn_i · x_i · r` and
/// `dx_j = r·g_j·dxn_j − x_j·r³·(Σ_i dxn_i g_i x_i)/h`.
fn rmsnorm_bwd(x: &[f32], g: &[f32], dxn: &[f32], n: usize, h: usize) -> (Vec<f32>, Vec<f32>) {
    counters::alloc_f32(n * h + h);
    let mut dx = vec![0.0f32; n * h];
    let mut dg = vec![0.0f32; h];
    rmsnorm_bwd_into(x, g, dxn, n, h, &mut dx, &mut dg);
    (dx, dg)
}

/// Tanh-approximate GeLU (JAX's `jax.nn.gelu` default).
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    const A: f32 = 0.044715;
    0.5 * x * (1.0 + (C * (x + A * x * x * x)).tanh())
}

/// Derivative of [`gelu`].
fn dgelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_6;
    const A: f32 = 0.044715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// Key/value tile width of the flash-attention streaming softmax.
const ATT_TILE: usize = 64;

/// Flash-attention-style causal multi-head attention forward over
/// flattened `[n, nh*hd]` q/k/v (rows grouped per batch: `n = b·s`),
/// following `python/compile/kernels/flash_attention.py`: per query row,
/// stream the causal keys `j ≤ i` in [`ATT_TILE`]-wide tiles through an
/// online softmax — running max `m`, running denominator `l`, and an
/// output accumulator rescaled by `exp(m − m_new)` per tile — so the
/// `[s, s]` score matrix is **never materialized** (O(`ATT_TILE`) scratch
/// instead of O(s²)). Returns the attention output and each row's
/// log-sum-exp `m + ln l`, from which the backward recomputes any
/// probability as `exp(qᵀk·scale − lse)`.
#[allow(clippy::too_many_arguments)]
pub fn attention_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
    out: &mut [f32],
    lse: &mut [f32],
) {
    counters::launch();
    let w = nh * hd; // row width
    debug_assert_eq!(out.len(), b * s * w);
    debug_assert_eq!(lse.len(), b * nh * s);
    let scale = 1.0 / (hd as f32).sqrt();
    // per-row output accumulator, hoisted to grow-only thread-local
    // scratch: it used to be a fresh `Vec` per kernel call
    scratch::ACC.with(|cell| {
        let mut buf = cell.borrow_mut();
        if buf.len() < hd {
            buf.resize(hd, 0.0);
        }
        let acc = &mut buf[..hd];
        for bi in 0..b {
            for hi in 0..nh {
                for i in 0..s {
                    let qrow = &q[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                    let mut m = f32::NEG_INFINITY;
                    let mut l = 0.0f32;
                    acc.fill(0.0);
                    let mut j0 = 0usize;
                    while j0 <= i {
                        let j1 = (j0 + ATT_TILE).min(i + 1);
                        let mut logits = [0.0f32; ATT_TILE];
                        let mut tile_max = f32::NEG_INFINITY;
                        for (t, logit) in logits.iter_mut().take(j1 - j0).enumerate() {
                            let j = j0 + t;
                            let krow =
                                &k[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                            *logit = dot(qrow, krow) * scale;
                            tile_max = tile_max.max(*logit);
                        }
                        // rescale the running state to the new max, then fold
                        // the tile in (exp(-inf) = 0 covers the first tile)
                        let m_new = m.max(tile_max);
                        let alpha = (m - m_new).exp();
                        l *= alpha;
                        for a in acc.iter_mut() {
                            *a *= alpha;
                        }
                        for (t, &logit) in logits.iter().take(j1 - j0).enumerate() {
                            let j = j0 + t;
                            let p = (logit - m_new).exp();
                            l += p;
                            let vrow =
                                &v[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                            axpy(acc, vrow, p);
                        }
                        m = m_new;
                        j0 = j1;
                    }
                    let orow =
                        &mut out[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                    for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                        *o = a / l;
                    }
                    lse[(bi * nh + hi) * s + i] = m + l.ln();
                }
            }
        }
    });
}

#[allow(clippy::too_many_arguments)]
pub fn attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>) {
    let w = nh * hd;
    counters::alloc_f32(b * s * w + b * nh * s);
    let mut out = vec![0.0f32; b * s * w];
    let mut lse = vec![0.0f32; b * nh * s];
    attention_into(q, k, v, b, s, nh, hd, &mut out, &mut lse);
    (out, lse)
}

/// Backward of [`attention`], flash-style: given the forward output `o`
/// and per-row log-sum-exp `lse`, recompute each probability tile as
/// `exp(qᵀk·scale − lse)` — no stored score matrix — and use
/// `D_i = do_i · o_i` (= Σ_j p·dp) for the softmax pullback. Returns
/// `(dq, dk, dv)`.
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd_into(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    lse: &[f32],
    o: &[f32],
    do_: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    counters::launch();
    let w = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    debug_assert_eq!(dq.len(), b * s * w);
    debug_assert_eq!(dk.len(), b * s * w);
    debug_assert_eq!(dv.len(), b * s * w);
    dq.fill(0.0);
    dk.fill(0.0);
    dv.fill(0.0);
    for bi in 0..b {
        for hi in 0..nh {
            for i in 0..s {
                let base_i = (bi * s + i) * w + hi * hd;
                let qrow = &q[base_i..base_i + hd];
                let dorow = &do_[base_i..base_i + hd];
                let di = dot(dorow, &o[base_i..base_i + hd]);
                let lse_i = lse[(bi * nh + hi) * s + i];
                for j in 0..=i {
                    let base_j = (bi * s + j) * w + hi * hd;
                    let krow = &k[base_j..base_j + hd];
                    let p = (dot(qrow, krow) * scale - lse_i).exp();
                    let dp = dot(dorow, &v[base_j..base_j + hd]);
                    let ds = p * (dp - di) * scale;
                    axpy(&mut dq[base_i..base_i + hd], krow, ds);
                    axpy(&mut dk[base_j..base_j + hd], qrow, ds);
                    axpy(&mut dv[base_j..base_j + hd], dorow, p);
                }
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
pub fn attention_bwd(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    lse: &[f32],
    o: &[f32],
    do_: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let w = nh * hd;
    counters::alloc_f32(3 * b * s * w);
    let mut dq = vec![0.0f32; b * s * w];
    let mut dk = vec![0.0f32; b * s * w];
    let mut dv = vec![0.0f32; b * s * w];
    attention_bwd_into(q, k, v, lse, o, do_, b, s, nh, hd, &mut dq, &mut dk, &mut dv);
    (dq, dk, dv)
}

/// Scalar reference attention forward (materializes the full `[s, s]`
/// probability matrix) — kept as the property-test oracle for the flash
/// kernel. Returns the attention output and the row-softmax
/// probabilities (needed by [`attention_bwd_ref`]).
#[allow(clippy::too_many_arguments)]
pub fn attention_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>) {
    let w = nh * hd; // row width
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0.0f32; b * s * w];
    let mut probs = vec![0.0f32; b * nh * s * s];
    for bi in 0..b {
        for hi in 0..nh {
            let pbase = (bi * nh + hi) * s * s;
            for i in 0..s {
                let qrow = &q[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                // causal logits over j ≤ i
                let mut logits = vec![0.0f32; i + 1];
                let mut max = f32::NEG_INFINITY;
                for (j, logit) in logits.iter_mut().enumerate() {
                    let krow = &k[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                    let mut dot = 0.0f32;
                    for d in 0..hd {
                        dot += qrow[d] * krow[d];
                    }
                    *logit = dot * scale;
                    max = max.max(*logit);
                }
                let mut denom = 0.0f32;
                for logit in logits.iter_mut() {
                    *logit = (*logit - max).exp();
                    denom += *logit;
                }
                let orow =
                    &mut out[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                for (j, &e) in logits.iter().enumerate() {
                    let p = e / denom;
                    probs[pbase + i * s + j] = p;
                    let vrow = &v[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                    for d in 0..hd {
                        orow[d] += p * vrow[d];
                    }
                }
            }
        }
    }
    (out, probs)
}

/// Backward of [`attention_ref`] (consumes the stored probabilities):
/// given upstream `do_`, returns `(dq, dk, dv)`. The property-test
/// oracle for the flash [`attention_bwd`].
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd_ref(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    probs: &[f32],
    do_: &[f32],
    b: usize,
    s: usize,
    nh: usize,
    hd: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let w = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dq = vec![0.0f32; b * s * w];
    let mut dk = vec![0.0f32; b * s * w];
    let mut dv = vec![0.0f32; b * s * w];
    for bi in 0..b {
        for hi in 0..nh {
            let pbase = (bi * nh + hi) * s * s;
            for i in 0..s {
                let dorow =
                    &do_[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                // dp_ij = do_i · v_j ; row-softmax pullback needs Σ_j dp·p
                let mut dp = vec![0.0f32; i + 1];
                let mut dpp = 0.0f32;
                for (j, dpj) in dp.iter_mut().enumerate() {
                    let vrow = &v[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                    let mut dot = 0.0f32;
                    for d in 0..hd {
                        dot += dorow[d] * vrow[d];
                    }
                    *dpj = dot;
                    dpp += dot * probs[pbase + i * s + j];
                }
                let qrow = &q[(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                for (j, &dpj) in dp.iter().enumerate() {
                    let p = probs[pbase + i * s + j];
                    let ds = p * (dpj - dpp) * scale;
                    let krow = &k[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                    {
                        let dqrow = &mut dq
                            [(bi * s + i) * w + hi * hd..(bi * s + i) * w + (hi + 1) * hd];
                        for d in 0..hd {
                            dqrow[d] += ds * krow[d];
                        }
                    }
                    {
                        let dkrow = &mut dk
                            [(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                        for d in 0..hd {
                            dkrow[d] += ds * qrow[d];
                        }
                    }
                    let dvrow =
                        &mut dv[(bi * s + j) * w + hi * hd..(bi * s + j) * w + (hi + 1) * hd];
                    let p_ = probs[pbase + i * s + j];
                    for d in 0..hd {
                        dvrow[d] += p_ * dorow[d];
                    }
                }
            }
        }
    }
    (dq, dk, dv)
}

// -------------------------------------------------------------- artifacts

/// Embedding row gather into a caller-provided `[n, h]` buffer (every
/// row overwritten; tokens read directly as `&[i32]`, no tensor wrap).
pub fn embed_fwd_into(
    emb: &[f32],
    tokens: &[i32],
    h: usize,
    vocab: usize,
    out: &mut [f32],
) -> Result<()> {
    counters::launch();
    debug_assert_eq!(out.len(), tokens.len() * h);
    for (n, &id) in tokens.iter().enumerate() {
        let id = id as usize;
        if id >= vocab {
            return Err(Error::Runtime(format!("embed_fwd: token {id} ≥ vocab {vocab}")));
        }
        out[n * h..(n + 1) * h].copy_from_slice(&emb[id * h..(id + 1) * h]);
    }
    Ok(())
}

/// Embedding backward into a caller-provided `[vocab, h]` buffer
/// (zero-filled here; rows accumulate).
pub fn embed_bwd_into(
    tokens: &[i32],
    d: &[f32],
    h: usize,
    vocab: usize,
    demb: &mut [f32],
) -> Result<()> {
    counters::launch();
    debug_assert_eq!(demb.len(), vocab * h);
    demb.fill(0.0);
    for (n, &id) in tokens.iter().enumerate() {
        if id < 0 || id as usize >= vocab {
            return Err(Error::Runtime(format!("embed_bwd: token {id} outside vocab {vocab}")));
        }
        let id = id as usize;
        let row = &mut demb[id * h..(id + 1) * h];
        let drow = &d[n * h..(n + 1) * h];
        for i in 0..h {
            row[i] += drow[i];
        }
    }
    Ok(())
}

fn embed_fwd(cfg: &ManifestConfig, emb: &HostTensor, tok: &HostTensor) -> Result<HostTensor> {
    let h = cfg.hidden;
    let (b, s) = (tok.shape[0], tok.shape[1]); // symbolic dims, bound per call
    let e = emb.as_f32()?;
    let t = tok.as_i32()?;
    counters::alloc_f32(b * s * h);
    let mut out = vec![0.0f32; b * s * h];
    embed_fwd_into(e, t, h, cfg.vocab, &mut out)?;
    HostTensor::f32(vec![b, s, h], out)
}

fn embed_bwd(cfg: &ManifestConfig, tok: &HostTensor, dx: &HostTensor) -> Result<HostTensor> {
    let (h, v) = (cfg.hidden, cfg.vocab);
    let t = tok.as_i32()?;
    let d = dx.as_f32()?;
    counters::alloc_f32(v * h);
    let mut demb = vec![0.0f32; v * h];
    embed_bwd_into(t, d, h, v, &mut demb)?;
    HostTensor::f32(vec![v, h], demb)
}

/// Recomputed forward intermediates shared by block forward and backward
/// (`lse` replaces the old stored probability matrix: the flash backward
/// recomputes probabilities tile by tile from it).
struct BlockFwd {
    xn1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    lse: Vec<f32>,
    xn2: Vec<f32>,
    a: Vec<f32>,
    hh: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn block_forward_parts(
    cfg: &ManifestConfig,
    tp: usize,
    params: &[&HostTensor],
    x: &[f32],
    b: usize,
    s: usize,
) -> Result<BlockFwd> {
    let h = cfg.hidden;
    let n = b * s;
    let hl = h / tp;
    let fl = cfg.ffn / tp;
    let nh = cfg.heads / tp;
    let hd = h / cfg.heads;
    let g1 = params[0].as_f32()?;
    let wq = params[1].as_f32()?;
    let wk = params[2].as_f32()?;
    let wv = params[3].as_f32()?;
    let g2 = params[5].as_f32()?;
    let w1 = params[6].as_f32()?;

    let xn1 = rmsnorm(x, g1, n, h);
    let q = matmul(&xn1, wq, n, h, hl);
    let k = matmul(&xn1, wk, n, h, hl);
    let v = matmul(&xn1, wv, n, h, hl);
    let (att, lse) = attention(&q, &k, &v, b, s, nh, hd);

    let xn2 = rmsnorm(x, g2, n, h);
    let a = matmul(&xn2, w1, n, h, fl);
    counters::launch(); // gelu map pass
    counters::alloc_f32(a.len());
    let hh: Vec<f32> = a.iter().map(|&z| gelu(z)).collect();
    Ok(BlockFwd { xn1, q, k, v, att, lse, xn2, a, hh })
}

fn block_fwd(cfg: &ManifestConfig, tp: usize, inputs: &[&HostTensor]) -> Result<HostTensor> {
    let h = cfg.hidden;
    let (b, s) = (inputs[8].shape[0], inputs[8].shape[1]); // symbolic dims
    let n = b * s;
    let hl = h / tp;
    let fl = cfg.ffn / tp;
    let x = inputs[8].as_f32()?;
    let parts = block_forward_parts(cfg, tp, inputs, x, b, s)?;
    let wo = inputs[4].as_f32()?;
    let w2 = inputs[7].as_f32()?;
    let att_out = matmul(&parts.att, wo, n, hl, h);
    let mlp_out = matmul(&parts.hh, w2, n, fl, h);
    counters::launch(); // residual-sum pass
    counters::alloc_f32(att_out.len());
    let y: Vec<f32> = att_out.iter().zip(mlp_out.iter()).map(|(a, m)| a + m).collect();
    HostTensor::f32(vec![b, s, h], y)
}

fn block_bwd(cfg: &ManifestConfig, tp: usize, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
    let h = cfg.hidden;
    let (b, s) = (inputs[8].shape[0], inputs[8].shape[1]); // symbolic dims
    let n = b * s;
    let hl = h / tp;
    let fl = cfg.ffn / tp;
    let nh = cfg.heads / tp;
    let hd = h / cfg.heads;
    let x = inputs[8].as_f32()?;
    let dy = inputs[9].as_f32()?;
    let parts = block_forward_parts(cfg, tp, inputs, x, b, s)?;
    let g1 = inputs[0].as_f32()?;
    let wq = inputs[1].as_f32()?;
    let wk = inputs[2].as_f32()?;
    let wv = inputs[3].as_f32()?;
    let wo = inputs[4].as_f32()?;
    let g2 = inputs[5].as_f32()?;
    let w1 = inputs[6].as_f32()?;
    let w2 = inputs[7].as_f32()?;

    // ---- MLP branch
    let dw2 = matmul_tn(&parts.hh, dy, n, fl, h);
    let dhh = matmul_nt(dy, w2, n, h, fl);
    counters::launch(); // dgelu map pass
    counters::alloc_f32(dhh.len());
    let da: Vec<f32> =
        dhh.iter().zip(parts.a.iter()).map(|(&d, &z)| d * dgelu(z)).collect();
    let dw1 = matmul_tn(&parts.xn2, &da, n, h, fl);
    let dxn2 = matmul_nt(&da, w1, n, fl, h);
    let (dx_mlp, dg2) = rmsnorm_bwd(x, g2, &dxn2, n, h);

    // ---- attention branch
    let dwo = matmul_tn(&parts.att, dy, n, hl, h);
    let datt = matmul_nt(dy, wo, n, h, hl);
    let (dq, dk, dv) = attention_bwd(
        &parts.q, &parts.k, &parts.v, &parts.lse, &parts.att, &datt, b, s, nh, hd,
    );
    let dwq = matmul_tn(&parts.xn1, &dq, n, h, hl);
    let dwk = matmul_tn(&parts.xn1, &dk, n, h, hl);
    let dwv = matmul_tn(&parts.xn1, &dv, n, h, hl);
    let mut dxn1 = matmul_nt(&dq, wq, n, hl, h);
    let dxn1_k = matmul_nt(&dk, wk, n, hl, h);
    let dxn1_v = matmul_nt(&dv, wv, n, hl, h);
    counters::launch(); // dxn1 merge pass
    for i in 0..dxn1.len() {
        dxn1[i] += dxn1_k[i] + dxn1_v[i];
    }
    let (dx_att, dg1) = rmsnorm_bwd(x, g1, &dxn1, n, h);

    counters::launch(); // dx residual-merge pass
    counters::alloc_f32(dx_att.len());
    let dx: Vec<f32> = dx_att.iter().zip(dx_mlp.iter()).map(|(a, m)| a + m).collect();

    Ok(vec![
        HostTensor::f32(vec![b, s, h], dx)?,
        HostTensor::f32(vec![h], dg1)?,
        HostTensor::f32(vec![h, hl], dwq)?,
        HostTensor::f32(vec![h, hl], dwk)?,
        HostTensor::f32(vec![h, hl], dwv)?,
        HostTensor::f32(vec![hl, h], dwo)?,
        HostTensor::f32(vec![h], dg2)?,
        HostTensor::f32(vec![h, fl], dw1)?,
        HostTensor::f32(vec![fl, h], dw2)?,
    ])
}

fn head_step(
    cfg: &ManifestConfig,
    gf: &HostTensor,
    wout: &HostTensor,
    x: &HostTensor,
    targets: &HostTensor,
) -> Result<Vec<HostTensor>> {
    let (h, v) = (cfg.hidden, cfg.vocab);
    let (b, s) = (targets.shape[0], targets.shape[1]); // symbolic dims
    let n = b * s;
    let xf = x.as_f32()?;
    let g = gf.as_f32()?;
    let w = wout.as_f32()?;
    let t = targets.as_i32()?;

    // padding mask: target `-1` marks a padded position — it contributes
    // no loss and a zero gradient row, and the mean normalizes over the
    // *real* positions only, so a right-padded ragged micro-batch is
    // numerically identical to executing each window at its true length.
    let count = t.iter().filter(|&&tgt| tgt >= 0).count();
    if count == 0 {
        return Err(Error::Runtime("head_step: every target is masked".into()));
    }
    let xn = rmsnorm(xf, g, n, h);
    let logits = matmul(&xn, w, n, h, v);
    counters::launch(); // softmax-CE / dlogits pass
    counters::alloc_f32(n * v);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; n * v];
    for r in 0..n {
        if t[r] < 0 {
            continue; // masked: dlogits row stays zero
        }
        let row = &logits[r * v..(r + 1) * v];
        let tgt = t[r] as usize;
        if tgt >= v {
            return Err(Error::Runtime(format!("head_step: target {tgt} ≥ vocab {v}")));
        }
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for &l in row {
            denom += (l - max).exp();
        }
        let logz = max + denom.ln();
        loss += logz - row[tgt];
        let drow = &mut dlogits[r * v..(r + 1) * v];
        for j in 0..v {
            let p = (row[j] - max).exp() / denom;
            drow[j] = p / count as f32;
        }
        drow[tgt] -= 1.0 / count as f32;
    }
    loss /= count as f32;

    let dwout = matmul_tn(&xn, &dlogits, n, h, v);
    let dxn = matmul_nt(&dlogits, w, n, v, h);
    let (dx, dgf) = rmsnorm_bwd(xf, g, &dxn, n, h);

    Ok(vec![
        HostTensor::f32(vec![], vec![loss])?,
        HostTensor::f32(vec![b, s, h], dx)?,
        HostTensor::f32(vec![h], dgf)?,
        HostTensor::f32(vec![h, v], dwout)?,
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn randvec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.f32_signed() * scale).collect()
    }

    /// Central-difference gradient check of one scalar wiring.
    fn numgrad(f: &mut dyn FnMut(&[f32]) -> f32, x: &[f32], i: usize) -> f32 {
        let eps = 1e-2f32;
        let mut xp = x.to_vec();
        xp[i] += eps;
        let fp = f(&xp);
        xp[i] -= 2.0 * eps;
        let fm = f(&xp);
        (fp - fm) / (2.0 * eps)
    }

    #[test]
    fn rmsnorm_bwd_matches_numeric() {
        let mut rng = Rng::new(11);
        let (n, h) = (3, 8);
        let x = randvec(&mut rng, n * h, 1.0);
        let g = randvec(&mut rng, h, 1.0);
        let dxn = randvec(&mut rng, n * h, 1.0);
        let (dx, dg) = rmsnorm_bwd(&x, &g, &dxn, n, h);
        // scalar objective: Σ dxn ⊙ rmsnorm(x, g)
        let mut f_of_x =
            |xx: &[f32]| -> f32 { rmsnorm(xx, &g, n, h).iter().zip(dxn.iter()).map(|(a, b)| a * b).sum() };
        for i in [0usize, 5, 17] {
            let num = numgrad(&mut f_of_x, &x, i);
            assert!((dx[i] - num).abs() < 2e-2, "dx[{i}] = {} vs numeric {num}", dx[i]);
        }
        let mut f_of_g =
            |gg: &[f32]| -> f32 { rmsnorm(&x, gg, n, h).iter().zip(dxn.iter()).map(|(a, b)| a * b).sum() };
        for i in [0usize, 3, 7] {
            let num = numgrad(&mut f_of_g, &g, i);
            assert!((dg[i] - num).abs() < 2e-2, "dg[{i}] = {} vs numeric {num}", dg[i]);
        }
    }

    #[test]
    fn blocked_gemms_match_naive_reference() {
        let mut rng = Rng::new(99);
        let (m, k, n) = (7, 131, 9); // awkward sizes: exercise tails + blocks
        let a = randvec(&mut rng, m * k, 1.0);
        let b = randvec(&mut rng, k * n, 1.0);
        let naive = |a: &[f32], b: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; m * n];
            for i in 0..m {
                for kk in 0..k {
                    for j in 0..n {
                        out[i * n + j] += a[i * k + kk] * b[kk * n + j];
                    }
                }
            }
            out
        };
        // k-ordered accumulation: bit-identical to the naive loop
        assert_eq!(matmul(&a, &b, m, k, n), naive(&a, &b));
        let tn = matmul_tn(&a, &b, 3, 5, 7);
        let mut tn_ref = vec![0.0f32; 5 * 7];
        for i in 0..3 {
            for kk in 0..5 {
                for j in 0..7 {
                    tn_ref[kk * 7 + j] += a[i * 5 + kk] * b[i * 7 + j];
                }
            }
        }
        assert_eq!(tn, tn_ref);
        let nt = matmul_nt(&a, &b, 4, 130, 6);
        for i in 0..4 {
            for kk in 0..6 {
                let mut s = 0.0f64;
                for j in 0..130 {
                    s += a[i * 130 + j] as f64 * b[kk * 130 + j] as f64;
                }
                let got = nt[i * 6 + kk] as f64;
                assert!((got - s).abs() < 1e-3 * s.abs().max(1.0), "nt[{i},{kk}] {got} vs {s}");
            }
        }
    }

    #[test]
    fn gelu_derivative_matches_numeric() {
        for x in [-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3f32;
            let num = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            assert!((dgelu(x) - num).abs() < 1e-3, "x={x}: {} vs {num}", dgelu(x));
        }
    }

    #[test]
    fn attention_bwd_matches_numeric() {
        let mut rng = Rng::new(5);
        let (b, s, nh, hd) = (1, 4, 2, 3);
        let w = nh * hd;
        let q = randvec(&mut rng, b * s * w, 0.5);
        let k = randvec(&mut rng, b * s * w, 0.5);
        let v = randvec(&mut rng, b * s * w, 0.5);
        let dout = randvec(&mut rng, b * s * w, 1.0);
        let (_, probs) = attention_ref(&q, &k, &v, b, s, nh, hd);
        let (dq, dk, dv) = attention_bwd_ref(&q, &k, &v, &probs, &dout, b, s, nh, hd);
        let obj = |qq: &[f32], kk: &[f32], vv: &[f32]| -> f32 {
            attention_ref(qq, kk, vv, b, s, nh, hd)
                .0
                .iter()
                .zip(dout.iter())
                .map(|(a, d)| a * d)
                .sum()
        };
        for i in [0usize, 7, 23] {
            let mut fq = |z: &[f32]| obj(z, &k, &v);
            let num = numgrad(&mut fq, &q, i);
            assert!((dq[i] - num).abs() < 3e-2, "dq[{i}] {} vs {num}", dq[i]);
            let mut fk = |z: &[f32]| obj(&q, z, &v);
            let num = numgrad(&mut fk, &k, i);
            assert!((dk[i] - num).abs() < 3e-2, "dk[{i}] {} vs {num}", dk[i]);
            let mut fv = |z: &[f32]| obj(&q, &k, z);
            let num = numgrad(&mut fv, &v, i);
            assert!((dv[i] - num).abs() < 3e-2, "dv[{i}] {} vs {num}", dv[i]);
        }
    }

    #[test]
    fn flash_attention_matches_reference_on_ragged_shapes() {
        // the flash kernel vs the full-matrix oracle across shapes that
        // exercise single-tile, multi-tile (s > ATT_TILE), and ragged
        // [n_seqs, seq_len] geometries
        for (case, &(b, s, nh, hd)) in
            [(1usize, 80usize, 2usize, 4usize), (3, 5, 2, 4), (1, 33, 2, 4), (2, 7, 4, 3)]
                .iter()
                .enumerate()
        {
            let mut rng = Rng::new(31 + case as u64);
            let w = nh * hd;
            let q = randvec(&mut rng, b * s * w, 0.5);
            let k = randvec(&mut rng, b * s * w, 0.5);
            let v = randvec(&mut rng, b * s * w, 0.5);
            let dout = randvec(&mut rng, b * s * w, 1.0);
            let (out_ref, probs) = attention_ref(&q, &k, &v, b, s, nh, hd);
            let (out, lse) = attention(&q, &k, &v, b, s, nh, hd);
            crate::testutil::assert_allclose(
                &out,
                &out_ref,
                1e-5,
                1e-5,
                &format!("flash fwd case {case}"),
            );
            assert!(lse.iter().all(|l| l.is_finite()), "case {case}: lse finite");
            let (dq_r, dk_r, dv_r) =
                attention_bwd_ref(&q, &k, &v, &probs, &dout, b, s, nh, hd);
            let (dq, dk, dv) = attention_bwd(&q, &k, &v, &lse, &out, &dout, b, s, nh, hd);
            crate::testutil::assert_allclose(&dq, &dq_r, 1e-4, 1e-4, "flash dq");
            crate::testutil::assert_allclose(&dk, &dk_r, 1e-4, 1e-4, "flash dk");
            crate::testutil::assert_allclose(&dv, &dv_r, 1e-4, 1e-4, "flash dv");
        }
    }

    #[test]
    fn bf16_gemm_is_exact_vs_dequantized_matmul() {
        let mut rng = Rng::new(77);
        let (m, k, n) = (5, 131, 9); // awkward sizes: tails + k-blocks
        let a16: Vec<u16> =
            randvec(&mut rng, m * k, 1.0).iter().map(|&x| f32_to_bf16(x)).collect();
        let b16: Vec<u16> =
            randvec(&mut rng, k * n, 1.0).iter().map(|&x| f32_to_bf16(x)).collect();
        let a32: Vec<f32> = a16.iter().map(|&x| bf16_to_f32(x)).collect();
        let b32: Vec<f32> = b16.iter().map(|&x| bf16_to_f32(x)).collect();
        // same k-ordered accumulation + zero-skip ⇒ bit-identical
        assert_eq!(matmul_bf16(&a16, &b16, m, k, n), matmul(&a32, &b32, m, k, n));
    }

    #[test]
    fn bf16_conversion_rounds_to_nearest_even() {
        assert_eq!(f32_to_bf16(1.0), 0x3f80);
        assert_eq!(bf16_to_f32(0x3f80), 1.0);
        // halfway, even low bit: rounds down
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f80_8000)), 0x3f80);
        // halfway, odd low bit: rounds up to even
        assert_eq!(f32_to_bf16(f32::from_bits(0x3f81_8000)), 0x3f82);
        // round trip stays within the 8-bit-mantissa relative error
        let mut rng = Rng::new(13);
        for x in randvec(&mut rng, 64, 10.0) {
            let r = bf16_to_f32(f32_to_bf16(x));
            assert!((r - x).abs() <= x.abs() / 128.0, "{x} → {r}");
        }
    }

    #[test]
    fn simd_axpy_dispatch_is_bit_identical_to_scalar() {
        let mut rng = Rng::new(21);
        let src = randvec(&mut rng, 37, 1.0); // odd length: SIMD tail path
        let base = randvec(&mut rng, 37, 1.0);
        let mut got = base.clone();
        let mut want = base;
        axpy(&mut got, &src, 0.37);
        axpy_scalar(&mut want, &src, 0.37);
        assert_eq!(got, want); // axpy is elementwise ⇒ exact under any dispatch
        // dot reassociates under SIMD: tolerance, not bits
        let (d, ds) = (dot(&src, &got), dot_scalar(&src, &got));
        assert!((d - ds).abs() <= 1e-5 * ds.abs().max(1.0), "{d} vs {ds}");
    }

    #[test]
    fn tp_shards_sum_to_full_block_output() {
        // the Megatron partial-sum contract: Σ_shards block_fwd_tp{d} equals
        // block_fwd_tp1 on the unsharded parameters.
        let cfg = ManifestConfig { batch: 1, seq: 4, ..tiny_config() };
        let (h, f) = (cfg.hidden, cfg.ffn);
        let mut rng = Rng::new(42);
        let g1 = HostTensor::f32(vec![h], vec![1.0; h]).unwrap();
        let g2 = g1.clone();
        let wq = HostTensor::f32(vec![h, h], randvec(&mut rng, h * h, 0.05)).unwrap();
        let wk = HostTensor::f32(vec![h, h], randvec(&mut rng, h * h, 0.05)).unwrap();
        let wv = HostTensor::f32(vec![h, h], randvec(&mut rng, h * h, 0.05)).unwrap();
        let wo = HostTensor::f32(vec![h, h], randvec(&mut rng, h * h, 0.05)).unwrap();
        let w1 = HostTensor::f32(vec![h, f], randvec(&mut rng, h * f, 0.05)).unwrap();
        let w2 = HostTensor::f32(vec![f, h], randvec(&mut rng, f * h, 0.05)).unwrap();
        let x = HostTensor::f32(vec![1, 4, h], randvec(&mut rng, 4 * h, 0.5)).unwrap();

        let full = {
            let inputs = [&g1, &wq, &wk, &wv, &wo, &g2, &w1, &w2, &x];
            block_fwd(&cfg, 1, &inputs).unwrap()
        };

        let col = |t: &HostTensor, tp: usize, j: usize| -> HostTensor {
            let (r, c) = (t.shape[0], t.shape[1]);
            let w = c / tp;
            let src = t.as_f32().unwrap();
            let mut out = Vec::with_capacity(r * w);
            for row in 0..r {
                out.extend_from_slice(&src[row * c + j * w..row * c + (j + 1) * w]);
            }
            HostTensor::f32(vec![r, w], out).unwrap()
        };
        let rowsl = |t: &HostTensor, tp: usize, j: usize| -> HostTensor {
            let (r, c) = (t.shape[0], t.shape[1]);
            let hh = r / tp;
            let src = t.as_f32().unwrap();
            HostTensor::f32(vec![hh, c], src[j * hh * c..(j + 1) * hh * c].to_vec()).unwrap()
        };

        let tp = 2;
        let mut acc = vec![0.0f32; 4 * h];
        for j in 0..tp {
            let (wqj, wkj, wvj) = (col(&wq, tp, j), col(&wk, tp, j), col(&wv, tp, j));
            let woj = rowsl(&wo, tp, j);
            let w1j = col(&w1, tp, j);
            let w2j = rowsl(&w2, tp, j);
            let inputs = [&g1, &wqj, &wkj, &wvj, &woj, &g2, &w1j, &w2j, &x];
            let part = block_fwd(&cfg, tp, &inputs).unwrap();
            for (a, p) in acc.iter_mut().zip(part.as_f32().unwrap().iter()) {
                *a += p;
            }
        }
        crate::testutil::assert_allclose(
            &acc,
            full.as_f32().unwrap(),
            1e-4,
            1e-4,
            "tp2 partial sums vs full block",
        );
    }

    #[test]
    fn head_step_masks_padding_targets() {
        // a padded tail (target -1) contributes no loss and no gradient:
        // the [1, 3] padded call must equal the [1, 2] true-length call on
        // the unmasked prefix, with a zero gradient at the pad position
        let cfg = ManifestConfig { batch: 1, seq: 2, vocab: 7, hidden: 6, ..tiny_config() };
        let (h, v) = (cfg.hidden, cfg.vocab);
        let mut rng = Rng::new(8);
        let gf = HostTensor::f32(vec![h], randvec(&mut rng, h, 1.0)).unwrap();
        let wout = HostTensor::f32(vec![h, v], randvec(&mut rng, h * v, 0.3)).unwrap();
        let xrow = randvec(&mut rng, 2 * h, 0.5);
        let mut xpad = xrow.clone();
        xpad.extend(randvec(&mut rng, h, 0.5)); // arbitrary activations at the pad

        let x_true = HostTensor::f32(vec![1, 2, h], xrow).unwrap();
        let t_true = HostTensor::i32(vec![1, 2], vec![3, 5]).unwrap();
        let out_true = head_step(&cfg, &gf, &wout, &x_true, &t_true).unwrap();

        let x_pad = HostTensor::f32(vec![1, 3, h], xpad).unwrap();
        let t_pad = HostTensor::i32(vec![1, 3], vec![3, 5, -1]).unwrap();
        let out_pad = head_step(&cfg, &gf, &wout, &x_pad, &t_pad).unwrap();

        let (l_true, l_pad) = (out_true[0].as_f32().unwrap()[0], out_pad[0].as_f32().unwrap()[0]);
        assert!((l_true - l_pad).abs() < 1e-6, "masked loss {l_pad} vs true {l_true}");
        let dx_true = out_true[1].as_f32().unwrap();
        let dx_pad = out_pad[1].as_f32().unwrap();
        crate::testutil::assert_allclose(&dx_pad[..2 * h], dx_true, 1e-6, 1e-5, "dx prefix");
        assert!(dx_pad[2 * h..].iter().all(|&d| d == 0.0), "pad position must get zero dx");
        crate::testutil::assert_allclose(
            out_pad[3].as_f32().unwrap(),
            out_true[3].as_f32().unwrap(),
            1e-6,
            1e-5,
            "dwout under masking",
        );
        // fully-masked batches are a typed error, not a NaN
        let t_all = HostTensor::i32(vec![1, 3], vec![-1, -1, -1]).unwrap();
        assert!(head_step(&cfg, &gf, &wout, &x_pad, &t_all).is_err());
    }

    #[test]
    fn head_step_gradients_match_numeric() {
        let cfg = ManifestConfig { batch: 1, seq: 2, vocab: 7, hidden: 6, ..tiny_config() };
        let (h, v) = (cfg.hidden, cfg.vocab);
        let mut rng = Rng::new(3);
        let gf = HostTensor::f32(vec![h], randvec(&mut rng, h, 1.0)).unwrap();
        let wout = HostTensor::f32(vec![h, v], randvec(&mut rng, h * v, 0.3)).unwrap();
        let x = HostTensor::f32(vec![1, 2, h], randvec(&mut rng, 2 * h, 0.5)).unwrap();
        let tgt = HostTensor::i32(vec![1, 2], vec![3, 5]).unwrap();
        let out = head_step(&cfg, &gf, &wout, &x, &tgt).unwrap();
        let (loss, dx) = (out[0].as_f32().unwrap()[0], out[1].as_f32().unwrap().to_vec());
        assert!(loss > 0.0);
        let xv = x.as_f32().unwrap().to_vec();
        let mut f = |xx: &[f32]| -> f32 {
            let xt = HostTensor::f32(vec![1, 2, h], xx.to_vec()).unwrap();
            head_step(&cfg, &gf, &wout, &xt, &tgt).unwrap()[0].as_f32().unwrap()[0]
        };
        for i in [0usize, 5, 11] {
            let num = numgrad(&mut f, &xv, i);
            assert!((dx[i] - num).abs() < 2e-2, "dx[{i}] {} vs {num}", dx[i]);
        }
    }

    /// The `_into` variants must equal the allocating oracles bit for bit,
    /// even when the destination buffer starts full of garbage (the fused
    /// path reuses workspace slices across calls).
    #[test]
    fn into_kernels_bit_identical_to_oracles_on_dirty_buffers() {
        for (case, &(b, s, nh, hd)) in
            [(0usize, (1usize, 80usize, 2usize, 4usize)), (1, (3, 5, 2, 4)), (2, (2, 7, 4, 3))]
                .iter()
                .map(|(c, t)| (*c, t))
        {
            let mut rng = Rng::new(101 + case as u64);
            let n = b * s;
            let w = nh * hd;
            let (m, k, nn) = (n, w, 2 * w + 3); // awkward GEMM geometry
            let a = randvec(&mut rng, m * k, 0.7);
            let bb = randvec(&mut rng, k * nn, 0.7);
            let dirty = |len: usize| vec![777.0f32; len];

            let mut out = dirty(m * nn);
            matmul_into(&a, &bb, m, k, nn, &mut out);
            assert_eq!(out, matmul(&a, &bb, m, k, nn), "case {case}: matmul_into");

            let mut out = dirty(k * nn);
            matmul_tn_into(&a, &bb, m, k, nn, &mut out);
            assert_eq!(out, matmul_tn(&a, &bb, m, k, nn), "case {case}: matmul_tn_into");

            let nt_a = randvec(&mut rng, m * 9, 0.7);
            let nt_b = randvec(&mut rng, 5 * 9, 0.7);
            let mut nt_out = dirty(m * 5);
            matmul_nt_into(&nt_a, &nt_b, m, 9, 5, &mut nt_out);
            assert_eq!(nt_out, matmul_nt(&nt_a, &nt_b, m, 9, 5), "case {case}: matmul_nt_into");

            let g = randvec(&mut rng, w, 1.0);
            let x = randvec(&mut rng, n * w, 0.6);
            let mut xn = dirty(n * w);
            rmsnorm_into(&x, &g, n, w, &mut xn);
            assert_eq!(xn, rmsnorm(&x, &g, n, w), "case {case}: rmsnorm_into");

            let dxn = randvec(&mut rng, n * w, 1.0);
            let (mut dx, mut dg) = (dirty(n * w), dirty(w));
            rmsnorm_bwd_into(&x, &g, &dxn, n, w, &mut dx, &mut dg);
            let (dx_o, dg_o) = rmsnorm_bwd(&x, &g, &dxn, n, w);
            assert_eq!(dx, dx_o, "case {case}: rmsnorm_bwd_into dx");
            assert_eq!(dg, dg_o, "case {case}: rmsnorm_bwd_into dg");

            let q = randvec(&mut rng, n * w, 0.5);
            let kk2 = randvec(&mut rng, n * w, 0.5);
            let v = randvec(&mut rng, n * w, 0.5);
            let (mut att, mut lse) = (dirty(n * w), dirty(b * nh * s));
            attention_into(&q, &kk2, &v, b, s, nh, hd, &mut att, &mut lse);
            let (att_o, lse_o) = attention(&q, &kk2, &v, b, s, nh, hd);
            assert_eq!(att, att_o, "case {case}: attention_into out");
            assert_eq!(lse, lse_o, "case {case}: attention_into lse");

            let dout = randvec(&mut rng, n * w, 1.0);
            let (mut dq, mut dk, mut dv) = (dirty(n * w), dirty(n * w), dirty(n * w));
            attention_bwd_into(
                &q, &kk2, &v, &lse, &att, &dout, b, s, nh, hd, &mut dq, &mut dk, &mut dv,
            );
            let (dq_o, dk_o, dv_o) =
                attention_bwd(&q, &kk2, &v, &lse, &att, &dout, b, s, nh, hd);
            assert_eq!(dq, dq_o, "case {case}: attention_bwd_into dq");
            assert_eq!(dk, dk_o, "case {case}: attention_bwd_into dk");
            assert_eq!(dv, dv_o, "case {case}: attention_bwd_into dv");

            let vocab = 37;
            let emb = randvec(&mut rng, vocab * w, 0.4);
            let toks: Vec<i32> = (0..n).map(|i| ((i * 7 + case) % vocab) as i32).collect();
            let mut eout = dirty(n * w);
            embed_fwd_into(&emb, &toks, w, vocab, &mut eout).unwrap();
            for (row, &t) in toks.iter().enumerate() {
                assert_eq!(
                    &eout[row * w..(row + 1) * w],
                    &emb[t as usize * w..(t as usize + 1) * w],
                    "case {case}: embed_fwd_into row {row}"
                );
            }
            let dyv = randvec(&mut rng, n * w, 1.0);
            let mut demb = dirty(vocab * w);
            embed_bwd_into(&toks, &dyv, w, vocab, &mut demb).unwrap();
            let mut demb_o = vec![0.0f32; vocab * w];
            for (row, &t) in toks.iter().enumerate() {
                for i in 0..w {
                    demb_o[t as usize * w + i] += dyv[row * w + i];
                }
            }
            assert_eq!(demb, demb_o, "case {case}: embed_bwd_into");
        }
    }

    /// Fused epilogues vs the unfused two-kernel sequences: bit-identical
    /// by construction (epilogue after full GEMM, oracle element order).
    #[test]
    fn fused_epilogues_bit_identical_to_unfused_sequences() {
        for (case, &(m, k, n)) in
            [(0usize, (7usize, 131usize, 9usize)), (1, (1, 48, 96)), (2, (32, 96, 48))]
                .iter()
                .map(|(c, t)| (*c, t))
        {
            let mut rng = Rng::new(301 + case as u64);
            let a = randvec(&mut rng, m * k, 0.6);
            let b = randvec(&mut rng, k * n, 0.6);
            let bias = randvec(&mut rng, n, 0.3);

            // GEMM + GeLU (no bias): the block MLP's fused form
            let (mut pre, mut hh) = (vec![777.0f32; m * n], vec![777.0f32; m * n]);
            matmul_bias_gelu_into(&a, &b, None, m, k, n, &mut pre, &mut hh);
            let pre_o = matmul(&a, &b, m, k, n);
            let hh_o: Vec<f32> = pre_o.iter().map(|&z| gelu(z)).collect();
            assert_eq!(pre, pre_o, "case {case}: bias_gelu pre (no bias)");
            assert_eq!(hh, hh_o, "case {case}: bias_gelu out (no bias)");

            // GEMM + bias + GeLU
            let (mut pre, mut hh) = (vec![777.0f32; m * n], vec![777.0f32; m * n]);
            matmul_bias_gelu_into(&a, &b, Some(&bias), m, k, n, &mut pre, &mut hh);
            let mut pre_o = matmul(&a, &b, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    pre_o[i * n + j] += bias[j];
                }
            }
            let hh_o: Vec<f32> = pre_o.iter().map(|&z| gelu(z)).collect();
            assert_eq!(pre, pre_o, "case {case}: bias_gelu pre");
            assert_eq!(hh, hh_o, "case {case}: bias_gelu out");

            // GEMM + residual (commutes bitwise: res + gemm == gemm + res)
            let res = randvec(&mut rng, m * n, 0.8);
            let mut y = vec![777.0f32; m * n];
            matmul_residual_into(&a, &b, m, k, n, &res, &mut y);
            let gemm = matmul(&a, &b, m, k, n);
            let y_o: Vec<f32> = res.iter().zip(gemm.iter()).map(|(r, g)| r + g).collect();
            assert_eq!(y, y_o, "case {case}: residual epilogue");

            // NT-GEMM + dGeLU
            let dy = randvec(&mut rng, m * n, 0.9);
            let w2 = randvec(&mut rng, k * n, 0.6);
            let prek = randvec(&mut rng, m * k, 0.7);
            let mut da = vec![777.0f32; m * k];
            matmul_nt_dgelu_into(&dy, &w2, &prek, m, n, k, &mut da);
            let dhh = matmul_nt(&dy, &w2, m, n, k);
            let da_o: Vec<f32> =
                dhh.iter().zip(prek.iter()).map(|(&d, &z)| d * dgelu(z)).collect();
            assert_eq!(da, da_o, "case {case}: nt_dgelu epilogue");

            // bf16 GEMM against a persistent dequant panel
            let a16: Vec<u16> = a.iter().map(|&x| f32_to_bf16(x)).collect();
            let b16: Vec<u16> = b.iter().map(|&x| f32_to_bf16(x)).collect();
            let panel: Vec<f32> = b16.iter().map(|&x| bf16_to_f32(x)).collect();
            let mut out16 = vec![777.0f32; m * n];
            matmul_bf16_panel_into(&a16, &panel, m, k, n, &mut out16);
            assert_eq!(
                out16,
                matmul_bf16(&a16, &b16, m, k, n),
                "case {case}: bf16 panel GEMM"
            );
        }
    }
}
