//! The (simulated) heterogeneous GPU cluster — Appendix A.1, Table 3.
//!
//! The paper's testbed is 16×H800 + 32×H20 (ranks 0–15 are H800, 16–47 are
//! H20), 8 GPUs per node, NVLink intra-node, InfiniBand inter-node. We model
//! devices by their Table 3 characteristics (BF16 tensor-core TFLOPS, HBM
//! capacity, NVLink bandwidth) and links by fabric bandwidth + latency; the
//! [`crate::sim`] discrete-event simulator and the BSR planner's bandwidth
//! heuristic both read this topology through the [`Bandwidth`] trait.
//!
//! Beyond the paper's 48-GPU testbed, [`ClusterSpec`] generates seeded
//! synthetic clusters at arbitrary scale — hundreds to thousands of ranks,
//! mixed device generations per node, and a skewed inter-node bandwidth
//! matrix (`ib_node_gbps`) — the input space of the cluster-scale strategy
//! synthesis pass ([`crate::strategy::synth`]).

use crate::comm::Bandwidth;
use crate::hspmd::dg::Rank;

/// GPUs per node in the paper's cluster.
pub const GPUS_PER_NODE: u32 = 8;
/// Inter-node InfiniBand bandwidth (GB/s per GPU direction). The paper does
/// not state it; 400 Gb/s NDR ≈ 50 GB/s is the contemporary H800/H20
/// deployment default (substitution documented in DESIGN.md).
pub const IB_GBPS: f64 = 50.0;
/// Link latency for point-to-point messages (s).
pub const LINK_LATENCY_S: f64 = 10e-6;

/// A device model (Table 3 row).
#[derive(Clone, Copy, PartialEq, Debug)]
pub struct DeviceKind {
    /// Marketing name.
    pub name: &'static str,
    /// HBM capacity in GiB.
    pub mem_gib: f64,
    /// BF16 tensor-core peak TFLOPS.
    pub bf16_tflops: f64,
    /// NVLink bandwidth, GB/s (per direction, intra-node).
    pub nvlink_gbps: f64,
}

/// NVIDIA H800 (Table 3): 80 GB, 990 TFLOPS BF16, 400 GB/s NVLink.
pub const H800: DeviceKind =
    DeviceKind { name: "H800", mem_gib: 80.0, bf16_tflops: 990.0, nvlink_gbps: 400.0 };
/// NVIDIA H20 (Table 3): 96 GB, 148 TFLOPS BF16, 900 GB/s NVLink.
pub const H20: DeviceKind =
    DeviceKind { name: "H20", mem_gib: 96.0, bf16_tflops: 148.0, nvlink_gbps: 900.0 };
/// NVIDIA A100-SXM (generated-cluster palette): 80 GB, 312 TFLOPS BF16,
/// 600 GB/s NVLink — the mid-generation tier between H800 and H20 compute.
pub const A100: DeviceKind =
    DeviceKind { name: "A100", mem_gib: 80.0, bf16_tflops: 312.0, nvlink_gbps: 600.0 };
/// NVIDIA V100-SXM2 (generated-cluster palette): 32 GB, 125 TFLOPS
/// tensor-core FP16, 300 GB/s NVLink — the legacy tail of a mixed fleet.
pub const V100: DeviceKind =
    DeviceKind { name: "V100", mem_gib: 32.0, bf16_tflops: 125.0, nvlink_gbps: 300.0 };

/// One physical device slot in the cluster.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    /// Global rank.
    pub rank: Rank,
    /// Hardware model.
    pub kind: DeviceKind,
    /// Node index (8 GPUs per node).
    pub node: u32,
    /// False once failed/removed (elastic scenarios).
    pub alive: bool,
}

/// The cluster: an ordered device table plus fabric parameters.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// All device slots (including failed ones, marked dead).
    pub devices: Vec<Device>,
    /// Inter-node bandwidth (GB/s) — the uniform default.
    pub ib_gbps: f64,
    /// Per-node inter-node bandwidth (GB/s), indexed by node. Empty means
    /// a uniform fabric at [`Cluster::ib_gbps`]; when populated, a
    /// cross-node link runs at the *slower* endpoint's node bandwidth
    /// (the skewed matrices of generated clusters — see [`ClusterSpec`]).
    pub ib_node_gbps: Vec<f64>,
}

impl Cluster {
    /// Build a cluster from homogeneous blocks: `(kind, count)` in rank
    /// order, packed into nodes of [`GPUS_PER_NODE`].
    pub fn from_blocks(blocks: &[(DeviceKind, u32)]) -> Cluster {
        let mut devices = vec![];
        let mut rank: Rank = 0;
        for &(kind, count) in blocks {
            for _ in 0..count {
                devices.push(Device { rank, kind, node: rank / GPUS_PER_NODE, alive: true });
                rank += 1;
            }
        }
        Cluster { devices, ib_gbps: IB_GBPS, ib_node_gbps: vec![] }
    }

    /// The paper's full testbed: 16×H800 (ranks 0–15) + 32×H20 (16–47).
    pub fn h800_16_h20_32() -> Cluster {
        Cluster::from_blocks(&[(H800, 16), (H20, 32)])
    }

    /// 16×H800 + 24×H20 (three H20 nodes).
    pub fn h800_16_h20_24() -> Cluster {
        Cluster::from_blocks(&[(H800, 16), (H20, 24)])
    }

    /// 16×H800 + 16×H20.
    pub fn h800_16_h20_16() -> Cluster {
        Cluster::from_blocks(&[(H800, 16), (H20, 16)])
    }

    /// Homogeneous H20 cluster of `n` GPUs.
    pub fn h20(n: u32) -> Cluster {
        Cluster::from_blocks(&[(H20, n)])
    }

    /// Homogeneous H800 cluster of `n` GPUs.
    pub fn h800(n: u32) -> Cluster {
        Cluster::from_blocks(&[(H800, n)])
    }

    /// Number of device slots (alive or not).
    pub fn len(&self) -> usize {
        self.devices.len()
    }

    /// True if no devices.
    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Device by rank.
    pub fn device(&self, rank: Rank) -> &Device {
        &self.devices[rank as usize]
    }

    /// Alive ranks, ascending.
    pub fn alive_ranks(&self) -> Vec<Rank> {
        self.devices.iter().filter(|d| d.alive).map(|d| d.rank).collect()
    }

    /// Mark a single GPU failed (elastic trace events).
    pub fn fail_gpu(&mut self, rank: Rank) {
        self.devices[rank as usize].alive = false;
    }

    /// Mark a whole node (8 GPUs) failed.
    pub fn fail_node(&mut self, node: u32) {
        for d in &mut self.devices {
            if d.node == node {
                d.alive = false;
            }
        }
    }

    /// Restore a rank (rejoin after repair).
    pub fn restore_gpu(&mut self, rank: Rank) {
        self.devices[rank as usize].alive = true;
    }

    /// Effective point-to-point bandwidth between two ranks (GB/s).
    pub fn link_gbps(&self, a: Rank, b: Rank) -> f64 {
        if a == b {
            return f64::INFINITY;
        }
        let da = self.device(a);
        let db = self.device(b);
        if da.node == db.node {
            da.kind.nvlink_gbps.min(db.kind.nvlink_gbps)
        } else {
            self.node_ib_gbps(da.node).min(self.node_ib_gbps(db.node))
        }
    }

    /// Inter-node bandwidth at one node's uplink (GB/s): the per-node skew
    /// entry when one exists, the uniform fabric default otherwise.
    pub fn node_ib_gbps(&self, node: u32) -> f64 {
        self.ib_node_gbps.get(node as usize).copied().unwrap_or(self.ib_gbps)
    }

    /// Time to move `bytes` between two ranks (s).
    pub fn transfer_s(&self, a: Rank, b: Rank, bytes: u64) -> f64 {
        if a == b {
            return 0.0;
        }
        LINK_LATENCY_S + bytes as f64 / (self.link_gbps(a, b) * 1e9)
    }

    /// Ring-collective time estimate for a group (s): `2(n-1)/n · bytes`
    /// over the slowest link for all-reduce, `(n-1)/n` for RS/AG.
    pub fn collective_s(&self, group: &[Rank], bytes: u64, all_reduce: bool) -> f64 {
        let n = group.len();
        if n <= 1 {
            return 0.0;
        }
        let mut min_gbps = f64::INFINITY;
        for w in 0..n {
            let a = group[w];
            let b = group[(w + 1) % n];
            min_gbps = min_gbps.min(self.link_gbps(a, b));
        }
        let factor = if all_reduce { 2.0 } else { 1.0 };
        let steps = (n - 1) as f64;
        factor * steps / n as f64 * bytes as f64 / (min_gbps * 1e9) + steps * LINK_LATENCY_S
    }
}

impl Bandwidth for Cluster {
    fn gbps(&self, from: Rank, to: Rank) -> f64 {
        self.link_gbps(from, to)
    }
    fn intra_node(&self, from: Rank, to: Rank) -> bool {
        self.device(from).node == self.device(to).node
    }
}

/// Seeded generator for synthetic clusters at arbitrary scale: each node
/// draws one device generation from the palette (whole nodes are
/// homogeneous, like real fleets) and one inter-node uplink bandwidth from
/// a skewed range, so the same seed always reproduces the same topology.
/// 128 nodes = 1024 ranks — the scale target of the synthesis pass.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// PRNG seed; equal seeds build identical clusters.
    pub seed: u64,
    /// Node count ([`GPUS_PER_NODE`] devices each).
    pub nodes: u32,
    /// Device-generation palette one kind per node is drawn from.
    pub kinds: Vec<DeviceKind>,
    /// Bandwidth skew in `[0, 1)`: each node's uplink is drawn uniformly
    /// from `[(1 - skew) · IB_GBPS, IB_GBPS]`. 0 keeps the fabric uniform.
    pub ib_skew: f64,
}

impl ClusterSpec {
    /// A spec with the default palette (H800/H20/A100) and a 0.5 skew.
    pub fn new(seed: u64, nodes: u32) -> ClusterSpec {
        ClusterSpec { seed, nodes, kinds: vec![H800, H20, A100], ib_skew: 0.5 }
    }

    /// Total device slots the built cluster will have.
    pub fn num_ranks(&self) -> u32 {
        self.nodes * GPUS_PER_NODE
    }

    /// Materialize the cluster (deterministic in the spec).
    pub fn build(&self) -> Cluster {
        let mut rng = crate::testutil::Rng::new(self.seed ^ 0xC1A5_7E25_EED5_0001);
        let mut devices = Vec::with_capacity(self.num_ranks() as usize);
        let mut ib_node_gbps = Vec::with_capacity(self.nodes as usize);
        let mut rank: Rank = 0;
        for node in 0..self.nodes {
            let kind = *rng.pick(&self.kinds);
            for _ in 0..GPUS_PER_NODE {
                devices.push(Device { rank, kind, node, alive: true });
                rank += 1;
            }
            ib_node_gbps.push(IB_GBPS * (1.0 - self.ib_skew * rng.f64()));
        }
        Cluster { devices, ib_gbps: IB_GBPS, ib_node_gbps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_layout() {
        let c = Cluster::h800_16_h20_32();
        assert_eq!(c.len(), 48);
        assert_eq!(c.device(0).kind.name, "H800");
        assert_eq!(c.device(15).kind.name, "H800");
        assert_eq!(c.device(16).kind.name, "H20");
        assert_eq!(c.device(47).kind.name, "H20");
        // node packing: 8 per node
        assert_eq!(c.device(7).node, 0);
        assert_eq!(c.device(8).node, 1);
        assert_eq!(c.device(16).node, 2);
    }

    #[test]
    fn intra_vs_inter_node_bandwidth() {
        let c = Cluster::h800_16_h20_32();
        // both H800, same node → 400 GB/s NVLink
        assert_eq!(c.link_gbps(0, 1), 400.0);
        // H20 same node → 900
        assert_eq!(c.link_gbps(16, 17), 900.0);
        // cross-node → IB
        assert_eq!(c.link_gbps(0, 8), IB_GBPS);
        assert_eq!(c.link_gbps(15, 16), IB_GBPS);
    }

    #[test]
    fn failures_update_alive_set() {
        let mut c = Cluster::h20(32);
        assert_eq!(c.alive_ranks().len(), 32);
        c.fail_gpu(31);
        assert_eq!(c.alive_ranks().len(), 31);
        c.fail_node(0);
        assert_eq!(c.alive_ranks().len(), 23);
        c.restore_gpu(31);
        assert_eq!(c.alive_ranks().len(), 24);
        assert!(c.device(31).alive);
    }

    #[test]
    fn collective_time_scales_with_group() {
        let c = Cluster::h20(8);
        let t2 = c.collective_s(&[0, 1], 1 << 30, true);
        let t8 = c.collective_s(&[0, 1, 2, 3, 4, 5, 6, 7], 1 << 30, true);
        assert!(t8 > t2); // (n-1)/n grows
        let rs = c.collective_s(&[0, 1, 2, 3], 1 << 30, false);
        let ar = c.collective_s(&[0, 1, 2, 3], 1 << 30, true);
        assert!((ar / rs - 2.0).abs() < 0.05);
    }

    #[test]
    fn transfer_time_includes_latency() {
        let c = Cluster::h20(16);
        assert!(c.transfer_s(0, 8, 0) > 0.0);
        assert_eq!(c.transfer_s(3, 3, 1 << 20), 0.0);
    }

    #[test]
    fn generated_clusters_are_deterministic_and_node_homogeneous() {
        let spec = ClusterSpec::new(7, 128);
        let a = spec.build();
        let b = spec.build();
        assert_eq!(a.len(), 1024);
        assert_eq!(a.ib_node_gbps.len(), 128);
        for (da, db) in a.devices.iter().zip(b.devices.iter()) {
            assert_eq!(da.kind.name, db.kind.name);
            assert_eq!(da.node, db.node);
        }
        assert_eq!(a.ib_node_gbps, b.ib_node_gbps);
        // whole nodes are homogeneous
        for n in 0..128u32 {
            let kinds: Vec<&str> = a
                .devices
                .iter()
                .filter(|d| d.node == n)
                .map(|d| d.kind.name)
                .collect();
            assert_eq!(kinds.len(), 8);
            assert!(kinds.iter().all(|&k| k == kinds[0]), "node {n} mixes kinds");
        }
        // mixed generations actually show up at this scale
        let names: std::collections::BTreeSet<&str> =
            a.devices.iter().map(|d| d.kind.name).collect();
        assert!(names.len() >= 2, "128-node palette draw is mixed: {names:?}");
    }

    #[test]
    fn skewed_node_uplinks_bound_cross_node_links() {
        let spec = ClusterSpec::new(3, 16);
        let c = spec.build();
        for &ib in &c.ib_node_gbps {
            assert!(ib <= IB_GBPS && ib >= IB_GBPS * 0.5, "uplink {ib} out of skew range");
        }
        // a cross-node link runs at the slower endpoint's uplink
        let t = c.link_gbps(0, 8);
        assert_eq!(t, c.node_ib_gbps(0).min(c.node_ib_gbps(1)));
        // intra-node stays NVLink
        let nv = c.device(0).kind.nvlink_gbps;
        assert_eq!(c.link_gbps(0, 1), nv);
        // uniform clusters are unaffected (empty skew table)
        let u = Cluster::h20(16);
        assert!(u.ib_node_gbps.is_empty());
        assert_eq!(u.link_gbps(0, 8), IB_GBPS);
    }

    #[test]
    fn bandwidth_trait_consistency() {
        let c = Cluster::h800_16_h20_32();
        assert!(c.intra_node(16, 17));
        assert!(!c.intra_node(15, 16));
        assert_eq!(c.gbps(16, 17), 900.0);
    }
}
