//! Regenerators for every table and figure in the paper's evaluation.
//!
//! Each function reproduces one artifact of §7/§8 as a [`Table`] (plus any
//! series data), on the simulated Table-3 cluster. Bench harnesses
//! (`rust/benches/`) print these; tests assert the qualitative *shape*
//! (who wins, by roughly what factor) matches the paper.

use crate::baselines::{deepspeed, hexiscale, hotspa, megatron};
use crate::cluster::Cluster;
use crate::comm::BsrOptions;
use crate::costmodel::{CostModel, ModelCfg};
use crate::data::{sample_step, Corpus};
use crate::elastic::{self, System};
use crate::metrics::{fmt_s, Stats, Table};
use crate::sim::simulate_step;
use crate::strategy::tables as stables;
use crate::strategy::ParallelStrategy;
use crate::switch::{plan_strategy_switch, plan_strategy_switch_avoiding};
use crate::testutil::Rng;
use crate::Result;

/// Global batch used throughout §7.1–7.2.
pub const GBS: u64 = 64;
/// Context length of §7.1–7.2.
pub const SEQ: u64 = 4096;

/// One Fig 13 cell: per-step time of every system on one (model, cluster).
pub struct Fig13Row {
    /// Scenario label.
    pub label: String,
    /// (system, seconds) pairs.
    pub times: Vec<(&'static str, f64)>,
}

/// Fig 13 — training time per step across model sizes and clusters.
pub fn fig13() -> Result<(Table, Vec<Fig13Row>)> {
    let mut table = Table::new(
        "Fig 13 — per-step training time (s), heterogeneous clusters",
        &["scenario", "DeepSpeed", "Megatron", "HexiScale", "Hetu"],
    );
    let mut rows = vec![];

    struct Case {
        label: &'static str,
        model: ModelCfg,
        cluster: Cluster,
        h800: u32,
        h20: u32,
        hetu: Option<ParallelStrategy>,
    }
    let cases = vec![
        Case {
            label: "32B 16xH800",
            model: ModelCfg::llama_32b(),
            cluster: Cluster::h800(16),
            h800: 16,
            h20: 0,
            hetu: None, // homogeneous: Hetu == Megatron layout
        },
        Case {
            label: "32B 16xH20",
            model: ModelCfg::llama_32b(),
            cluster: Cluster::h20(16),
            h800: 0,
            h20: 16,
            hetu: None,
        },
        Case {
            label: "32B 16xH800+16xH20",
            model: ModelCfg::llama_32b(),
            cluster: Cluster::h800_16_h20_16(),
            h800: 16,
            h20: 16,
            hetu: Some(stables::hetu_32b_16h800_16h20()),
        },
        Case {
            label: "32B 16xH800+24xH20",
            model: ModelCfg::llama_32b(),
            cluster: Cluster::h800_16_h20_24(),
            h800: 16,
            h20: 24,
            hetu: Some(stables::hetu_32b_16h800_24h20()),
        },
        Case {
            label: "32B 16xH800+32xH20",
            model: ModelCfg::llama_32b(),
            cluster: Cluster::h800_16_h20_32(),
            h800: 16,
            h20: 32,
            hetu: Some(stables::hetu_32b_16h800_32h20()),
        },
        Case {
            label: "70B 16xH800+16xH20",
            model: ModelCfg::llama_70b(),
            cluster: Cluster::h800_16_h20_16(),
            h800: 16,
            h20: 16,
            hetu: Some(stables::hetu_70b_16h800_16h20()),
        },
        Case {
            label: "70B 16xH800+24xH20",
            model: ModelCfg::llama_70b(),
            cluster: Cluster::h800_16_h20_24(),
            h800: 16,
            h20: 24,
            hetu: Some(stables::hetu_70b_16h800_24h20()),
        },
        Case {
            label: "70B 16xH800+32xH20",
            model: ModelCfg::llama_70b(),
            cluster: Cluster::h800_16_h20_32(),
            h800: 16,
            h20: 32,
            hetu: Some(stables::hetu_70b_16h800_32h20()),
        },
    ];

    for c in cases {
        let cm = CostModel::new(c.model);
        let ds = deepspeed::table4(c.model.name, c.h800, c.h20)
            .map(|cfg| deepspeed::step_time(&c.cluster, &cm, cfg, GBS, SEQ));
        let mg = megatron::table4(c.model.name, c.h800, c.h20)
            .map(|cfg| megatron::step_time(&c.cluster, &cm, cfg, GBS, SEQ))
            .transpose()?;
        let (hetu_t, hexi_t) = match &c.hetu {
            Some(h) => (
                simulate_step(&c.cluster, &cm, h)?.step_s,
                Some(hexiscale::step_time(&c.cluster, &cm, h)?),
            ),
            None => {
                // homogeneous cluster: Hetu runs the optimal uniform layout
                let cfg = megatron::table4(c.model.name, c.h800, c.h20).unwrap();
                (megatron::step_time(&c.cluster, &cm, cfg, GBS, SEQ)?, None)
            }
        };
        let fmt = |x: Option<f64>| x.map(fmt_s).unwrap_or_else(|| "-".into());
        table.row(vec![
            c.label.to_string(),
            fmt(ds),
            fmt(mg),
            fmt(hexi_t),
            fmt_s(hetu_t),
        ]);
        let mut times: Vec<(&'static str, f64)> = vec![("Hetu", hetu_t)];
        if let Some(t) = ds {
            times.push(("DeepSpeed", t));
        }
        if let Some(t) = mg {
            times.push(("Megatron", t));
        }
        if let Some(t) = hexi_t {
            times.push(("HexiScale", t));
        }
        rows.push(Fig13Row { label: c.label.to_string(), times });
    }
    Ok((table, rows))
}

/// Fig 14 — elastic traces: per-configuration step time + reconfiguration
/// overhead for all four systems, on both traces.
pub fn fig14() -> Result<Vec<(String, Table)>> {
    let cm = CostModel::new(ModelCfg::llama_32b());
    let mut out = vec![];
    for (name, scenario) in [
        ("homogeneous (32xH20)", elastic::homogeneous_trace()),
        ("heterogeneous (16xH800+32xH20)", elastic::heterogeneous_trace()),
    ] {
        let mut table = Table::new(
            &format!("Fig 14 — elastic training, {name}"),
            &["config", "GPUs", "Hetu", "Hetu reconf", "DeepSpeed", "DS reconf", "Megatron", "Mg reconf", "Oobleck", "Oob reconf"],
        );
        let hetu = elastic::run_scenario(&scenario, &cm, System::Hetu, GBS, SEQ)?;
        let ds = elastic::run_scenario(&scenario, &cm, System::DeepSpeed, GBS, SEQ)?;
        let mg = elastic::run_scenario(&scenario, &cm, System::Megatron, GBS, SEQ)?;
        let oob = elastic::run_scenario(&scenario, &cm, System::Oobleck, GBS, SEQ)?;
        for i in 0..hetu.len() {
            table.row(vec![
                hetu[i].name.clone(),
                hetu[i].gpus.to_string(),
                fmt_s(hetu[i].step_s),
                fmt_s(hetu[i].reconfig_s),
                fmt_s(ds[i].step_s),
                fmt_s(ds[i].reconfig_s),
                fmt_s(mg[i].step_s),
                fmt_s(mg[i].reconfig_s),
                fmt_s(oob[i].step_s),
                fmt_s(oob[i].reconfig_s),
            ]);
        }
        out.push((name.to_string(), table));
    }
    Ok(out)
}

/// Per-step times of one mixed-length configuration for all five systems.
pub struct Fig15Cell {
    /// e.g. "CommonCrawl 32K".
    pub label: String,
    /// System → per-step time samples over the simulated steps.
    pub samples: Vec<(&'static str, Vec<f64>)>,
}

/// Fig 15 — mixed-length per-step time distributions (box-plot data).
pub fn fig15(steps: usize) -> Result<(Table, Vec<Fig15Cell>)> {
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let mut table = Table::new(
        "Fig 15 — mixed-length per-step time (mean [p25,p75] s), 32B on 32xH20, 200K tok/step",
        &["workload", "DeepSpeed", "Megatron", "HotSPa", "Hetu-A", "Hetu-B"],
    );
    let mut cells = vec![];
    for corpus in [Corpus::CommonCrawl, Corpus::GitHub] {
        for ctx in [32768u64, 16384] {
            let label = format!(
                "{} {}K",
                if corpus == Corpus::CommonCrawl { "CommonCrawl" } else { "GitHub" },
                ctx / 1024
            );
            let mut rng = Rng::new(0xF15 ^ ctx);
            // per-pair switch costs for HotSPa (unfused) / Hetu-A (fused)
            let cfgs = hotspa::table10(ctx);
            let mut sw_unfused = vec![vec![0.0; cfgs.len()]; cfgs.len()];
            let mut sw_fused = vec![vec![0.0; cfgs.len()]; cfgs.len()];
            for i in 0..cfgs.len() {
                for j in 0..cfgs.len() {
                    if i == j {
                        continue;
                    }
                    let a = hotspa::bucket_strategy(&cluster, cfgs[i], cm.model.layers, GBS)?;
                    let b = hotspa::bucket_strategy(&cluster, cfgs[j], cm.model.layers, GBS)?;
                    sw_unfused[i][j] =
                        plan_strategy_switch(&a, &b, &cm, &cluster, BsrOptions { heuristics: false }, false)?
                            .est_seconds;
                    sw_fused[i][j] =
                        plan_strategy_switch(&a, &b, &cm, &cluster, BsrOptions::default(), true)?
                            .est_seconds;
                }
            }

            let mut ds_t = vec![];
            let mut mg_t = vec![];
            let mut hotspa_t = vec![];
            let mut hetu_a_t = vec![];
            let mut hetu_b_t = vec![];
            for _ in 0..steps {
                let batch = sample_step(&mut rng, corpus, 200_000, ctx);
                // packed baselines
                let packed = crate::data::pack_sequences(&batch.seq_lens, ctx).len() as u64;
                let ds_cfg = deepspeed::table9(ctx).unwrap();
                ds_t.push(deepspeed::step_time(&cluster, &cm, ds_cfg, packed, ctx));
                let mg_cfg = megatron::table9(ctx).unwrap();
                mg_t.push(megatron::step_time(&cluster, &cm, mg_cfg, packed, ctx)?);
                // bucket-switching systems
                hotspa_t.push(hotspa::step_time(&cluster, &cm, &batch, ctx, &|a, b| {
                    sw_unfused[a][b]
                })?);
                hetu_a_t.push(hotspa::step_time(&cluster, &cm, &batch, ctx, &|a, b| {
                    sw_fused[a][b]
                })?);
                // Hetu-B: heterogeneous strategies selected per step
                hetu_b_t.push(hetu_b_step(&cluster, &cm, &batch, ctx)?);
            }
            let f = |v: &[f64]| {
                let s = Stats::of(v);
                format!("{:.2} [{:.2},{:.2}]", s.mean, s.p25, s.p75)
            };
            table.row(vec![
                label.clone(),
                f(&ds_t),
                f(&mg_t),
                f(&hotspa_t),
                f(&hetu_a_t),
                f(&hetu_b_t),
            ]);
            cells.push(Fig15Cell {
                label,
                samples: vec![
                    ("DeepSpeed", ds_t),
                    ("Megatron", mg_t),
                    ("HotSPa", hotspa_t),
                    ("Hetu-A", hetu_a_t),
                    ("Hetu-B", hetu_b_t),
                ],
            });
        }
    }
    Ok((table, cells))
}

/// One Hetu-B step: select Strategy 1/2 by the batch's max sequence length
/// (Tables 11/12), dispatch sequences to pipelines by the cost model, and
/// simulate the heterogeneous strategy with per-pipeline micro-batching.
pub fn hetu_b_step(
    cluster: &Cluster,
    cm: &CostModel,
    batch: &crate::data::StepBatch,
    ctx: u64,
) -> Result<f64> {
    let max_len = batch.max_len();
    // Short-pipeline sequence caps: the paper distributes "sequences of
    // varying lengths across machines with different parallelisms" — the
    // TP4(±PP2) short pipelines take anything their memory allows (≤16K;
    // cf. Table 11's TP4 L0-59 pipelines on 96-GB H20s), so only the true
    // long tail lands on the wide pipeline.
    let (strat, long_seq, short_seq) = match (ctx, max_len) {
        (32768, l) if l > 16384 => (stables::hetu_b_32k_strategy1(32768), 32768u64, 16384u64),
        (32768, _) => (stables::hetu_b_32k_strategy2(16384), 16384, 16384),
        (16384, l) if l > 4096 => (stables::hetu_b_16k_strategy1(16384), 16384, 16384),
        (16384, _) => (stables::hetu_b_16k_strategy2(4096), 4096, 4096),
        _ => return Err(crate::Error::Strategy(format!("no Hetu-B table for ctx {ctx}"))),
    };
    // dispatch sequences: pipeline 0 is the long-sequence pipeline
    let classes: Vec<crate::data::PipeClass> = strat
        .pipelines
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let gpus: f64 = p.ranks().len() as f64;
            crate::data::PipeClass {
                max_seq: if i == 0 { long_seq } else { short_seq },
                tokens_per_s: gpus,
            }
        })
        .collect();
    let assign = crate::data::dispatch_hetu_b(&batch.seq_lens, &classes);
    // Each pipeline processes its assignment as ONE stream of variable-shape
    // micro-batches (§5.5 symbolic shapes): short sequences pack into
    // 4K-token micro-batches, longer sequences become their own
    // micro-batch of their actual length. The pipeline bubble is paid once
    // per step, not once per length class. The simulator takes a uniform
    // per-mb shape, so we feed it the token-matched average micro-batch.
    let mut worst = 0f64;
    for (i, seqs) in assign.iter().enumerate() {
        if seqs.is_empty() {
            continue;
        }
        let shorts: Vec<u64> = seqs.iter().copied().filter(|&l| l <= 4096).collect();
        let longs = seqs.iter().filter(|&&l| l > 4096).count() as u64;
        let num_mb =
            (crate::data::pack_sequences(&shorts, 4096).len() as u64 + longs).max(1);
        let total_tokens: u64 = seqs.iter().sum();
        let avg_seq = (total_tokens / num_mb).clamp(256, classes[i].max_seq);
        let mut p = strat.pipelines[i].clone();
        p.num_microbatches = num_mb as u32;
        p.microbatch_size = 1;
        let solo = ParallelStrategy {
            name: format!("{}-p{i}", strat.name),
            pipelines: vec![p],
            zero1: strat.zero1,
            schedule: strat.schedule,
            seq_len: avg_seq,
            ac: strat.ac,
        };
        worst = worst.max(simulate_step(cluster, cm, &solo)?.step_s);
    }
    // cross-pipeline gradient sync (SplitAR path): one AR of a layer-shard
    // volume across pipeline representatives
    let reps: Vec<u32> = strat.pipelines.iter().map(|p| p.ranks()[0]).collect();
    let bytes = (cm.model.params_per_layer() as f64 * cm.params.elem_bytes) as u64
        * cm.model.layers as u64
        / strat.pipelines[0].stages[0].tp() as u64;
    let sync = cluster.collective_s(&reps, bytes, true);
    Ok(worst + sync)
}

/// Fig 15, **measured**: drive the temporal runtime over a synthetic
/// CommonCrawl stream on the native-backend engine and report the
/// amortized per-step time (step makespans + non-overlapped switch
/// seconds) of each single static strategy vs. the Hetu-A/Hetu-B
/// switching engines — the engine-measured mirror of [`fig15`]'s
/// simulated cells, with switch overhead amortized over the bucket
/// run-length. Every cell executes *real ragged windows* (each step's
/// sequences packed into actual context windows and run at their true
/// scaled lengths — the `tok/step` and `pad` columns are measured from
/// the engine, and `pad` stays 0 because dispatcher-built windows never
/// pay padded context). Static strategies whose bucket context cannot
/// host the stream's longest sequence truncate (marked), which is why
/// the dynamic engines must beat the best *feasible* static one
/// (asserted in `rust/tests/engine_integration.rs`). Switch overhead is
/// **measured interleaved** (DESIGN.md §7.3): each switch's per-sender
/// delivery batches ride wire lanes inside the first post-switch step's
/// specialized timelines, and the tabled `exposed` column shows the
/// measured exposure against the old accounted
/// `max(0, Σ delivery − makespan)` bound it can never exceed (checked
/// before the rows are emitted).
pub fn fig15_engine(steps: usize) -> Result<Table> {
    use crate::coordinator::SyntheticCorpus;
    use crate::engine::EngineStrategy;
    use crate::runtime::{native, Runtime};
    use crate::temporal::{
        default_pool_entries, sample_stream, DispatchPolicy, Dispatcher, StrategyPool,
    };

    let tiny = native::tiny_config();
    let entries = default_pool_entries(&tiny)?;
    let mut rng = Rng::new(0xF15E);
    let stream = sample_stream(&mut rng, Corpus::CommonCrawl, steps, 100_000, 32_768);
    let stream_max = stream.iter().map(|b| b.max_len()).max().unwrap_or(0);
    let cm = CostModel::new(ModelCfg::llama_32b());

    let mut table = Table::new(
        "Fig 15 (engine-measured) — amortized per-step time, native tiny-48, synthetic CommonCrawl 32K, ragged windows",
        &["policy", "feasible", "switches", "cache hits", "mb/step", "tok/step", "pad", "exposed ms (meas/bound)", "amortized s/step"],
    );
    let mut cases = Vec::new();
    for (s, ctx) in &entries {
        let single: Vec<(EngineStrategy, u64)> = vec![(s.clone(), *ctx)];
        cases.push((format!("static {}", s.name), single, DispatchPolicy::HetuB));
    }
    cases.push(("Hetu-A (bucketize)".into(), entries.clone(), DispatchPolicy::HetuA));
    cases.push(("Hetu-B (cost model)".into(), entries.clone(), DispatchPolicy::HetuB));

    // one dispatcher for every cell, its engine-cell scale derived from
    // the *full* entry set's widest context (not the hard-coded 32K
    // default), so static and dynamic cells execute comparable token
    // cells
    let mut disp = Dispatcher::new(cm, DispatchPolicy::HetuB);
    disp.scale_cells(entries.iter().map(|(_, c)| *c).max().unwrap_or(0), tiny.seq);

    for (label, pe, policy) in cases {
        let feasible = pe.iter().map(|(_, c)| *c).max().unwrap_or(0) >= stream_max;
        let mut pool = StrategyPool::new(tiny, pe)?;
        let mut eng = pool.spawn_engine(Runtime::native(tiny), 0, 42, 1e-3)?;
        disp.policy = policy;
        let mut corpus = SyntheticCorpus::new(7, tiny.vocab);
        let rep = disp.run_stream(&mut eng, &mut pool, &stream, &mut corpus)?;
        // measured interleaved exposure (per-sender wire lanes inside the
        // first post-switch step) can never exceed the old accounted
        // max(0, Σ delivery − makespan) bookkeeping on the same stream
        let exposed: f64 = rep.steps.iter().map(|s| s.exposed_s).sum();
        let bound: f64 = rep.steps.iter().map(|s| s.exposed_bound_s).sum();
        if exposed > bound + 1e-9 {
            return Err(crate::Error::Engine(format!(
                "fig15_engine[{label}]: measured exposed switch time {exposed}s exceeds \
                 the accounted bound {bound}s"
            )));
        }
        let n = rep.steps.len().max(1) as f64;
        table.row(vec![
            label,
            if feasible { "yes".into() } else { "truncates".into() },
            rep.switches.to_string(),
            rep.cache_hits.to_string(),
            format!("{:.1}", rep.total_microbatches() as f64 / n),
            format!("{:.1}", rep.total_tokens() as f64 / n),
            rep.total_padded().to_string(),
            format!("{:.2}/{:.2}", exposed * 1e3, bound * 1e3),
            fmt_s(rep.amortized_step_s()),
        ]);
    }
    Ok(table)
}

/// Fig 16 — the per-step max-seq-len trace and Hetu-B's strategy choice.
pub fn fig16(steps: usize) -> Result<Table> {
    let mut table = Table::new(
        "Fig 16 — 32K CommonCrawl: per-step length stats and Hetu-B strategy",
        &["step", "#seqs", "max len", "p99", "%<8K", "strategy"],
    );
    let mut rng = Rng::new(0xF16);
    for step in 0..steps {
        let b = sample_step(&mut rng, Corpus::CommonCrawl, 200_000, 32768);
        let mut lens = b.seq_lens.clone();
        lens.sort_unstable();
        let p99 = lens[(0.99 * (lens.len() - 1) as f64) as usize];
        let under8k = lens.iter().filter(|&&l| l < 8192).count() as f64 / lens.len() as f64;
        let strategy = if b.max_len() > 16384 { "Strategy 1" } else { "Strategy 2" };
        table.row(vec![
            step.to_string(),
            b.seq_lens.len().to_string(),
            b.max_len().to_string(),
            p99.to_string(),
            format!("{:.1}%", under8k * 100.0),
            strategy.to_string(),
        ]);
    }
    Ok(table)
}

/// Fig 17 — the C2 deployment's resolved communication pattern.
pub fn fig17() -> Result<Table> {
    use crate::hspmd::ds::DUPLICATE;
    use crate::hspmd::{Annotation, DeviceGroup, DistStates, Subgroup};

    let cluster = Cluster::h20(32);
    let c2 = stables::hetu_c2_31h20();
    let mut table = Table::new(
        "Fig 17 — C2 strategy: resolved communication per edge",
        &["edge", "resolution", "detail"],
    );
    // Within-stage TP sync: partial -> dup on each stage (AG/RS pair in the
    // paper's Megatron formulation; our resolver reports the AR-class op).
    for (pi, p) in c2.pipelines.iter().enumerate() {
        for (si, s) in p.stages.iter().enumerate() {
            if s.tp() > 1 {
                let dg = DeviceGroup::new(s.ranks.clone())?;
                let src = Annotation::spmd(dg.clone(), DistStates::partial(s.tp()))?;
                let dst = Annotation::spmd(dg, DistStates::duplicate(s.tp()))?;
                let res = crate::comm::resolve(&src, &dst, &[4096, 6400], &cluster, BsrOptions::default())?;
                table.row(vec![
                    format!("P{pi} stage {si} TP sync"),
                    res.kind.to_string(),
                    format!("ranks {:?}", s.ranks),
                ]);
            }
            if si > 0 {
                let prev = &p.stages[si - 1];
                let src = Annotation::spmd(
                    DeviceGroup::new(prev.ranks.clone())?,
                    DistStates::duplicate(prev.tp()),
                )?;
                let dst = Annotation::spmd(
                    DeviceGroup::new(s.ranks.clone())?,
                    DistStates::duplicate(s.tp()),
                )?;
                let res = crate::comm::resolve(&src, &dst, &[4096, 6400], &cluster, BsrOptions::default())?;
                table.row(vec![
                    format!("P{pi} stage {}->{} activation", si - 1, si),
                    res.kind.to_string(),
                    format!("{} -> {} ranks", prev.tp(), s.tp()),
                ]);
            }
        }
    }
    // Cross-pipeline gradient sync per layer region: equal TP -> AR
    // (SplitAR when subgroup DSs differ, §4.2).
    for l in [0u32, 50, 59] {
        let holders = c2.holders_of_layer(l);
        if holders.len() < 2 {
            continue;
        }
        let groups: Vec<Subgroup> = holders
            .iter()
            .map(|h| {
                Subgroup::new(
                    DeviceGroup::new(h.ranks.clone()).unwrap(),
                    DistStates::split(0, h.tp()),
                )
                .unwrap()
            })
            .collect();
        let src = Annotation::new(groups.clone(), crate::hspmd::ds::PARTIAL)?;
        let dst = Annotation::new(groups, DUPLICATE)?;
        let shape = vec![crate::costmodel::ModelCfg::llama_32b().params_per_layer()];
        let res = crate::comm::resolve(&src, &dst, &shape, &cluster, BsrOptions::default())?;
        table.row(vec![
            format!("grad sync layer {l}"),
            res.kind.to_string(),
            format!(
                "{} subgroups, tp {:?}",
                holders.len(),
                holders.iter().map(|h| h.tp()).collect::<Vec<_>>()
            ),
        ]);
    }
    Ok(table)
}

/// Fig 18 left — per-rank compute/comm/bubble breakdown under C1 and C2.
pub fn fig18_left() -> Result<Table> {
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let mut table = Table::new(
        "Fig 18 (left) — time breakdown by rank",
        &["config", "rank", "compute", "comm", "bubble", "step"],
    );
    for (name, strat) in [("C1", stables::hetu_c1_32h20()), ("C2", stables::hetu_c2_31h20())] {
        let rep = simulate_step(&cluster, &cm, &strat)?;
        for rank in [0u32, 29] {
            if let Some(b) = rep.per_rank.get(&rank) {
                table.row(vec![
                    name.into(),
                    rank.to_string(),
                    fmt_s(b.compute_s),
                    fmt_s(b.comm_s),
                    fmt_s(b.bubble_s),
                    fmt_s(rep.step_s),
                ]);
            }
        }
    }
    Ok(table)
}

/// Fig 18 right — C1→C2 transition: specialization phases + switching time
/// under the three BSR planners.
pub fn fig18_right() -> Result<Table> {
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let c1 = stables::hetu_c1_32h20();
    let c2 = stables::hetu_c2_31h20();

    let mut table = Table::new(
        "Fig 18 (right) — C1→C2 transition overhead",
        &["component", "time", "notes"],
    );

    // Graph specialization phases, measured on a real 60-layer graph.
    let t0 = std::time::Instant::now();
    let (mut g, binding) = crate::figures::build_strategy_graph(&[&c1, &c2])?;
    let build_s = t0.elapsed().as_secs_f64();
    let t1 = std::time::Instant::now();
    let spec = crate::spec::instantiate::specialize(&mut g, 1, &binding, &cluster, BsrOptions::default())?;
    let spec_s = t1.elapsed().as_secs_f64();
    let t2 = std::time::Instant::now();
    let ranks: Vec<u32> = c2.ranks();
    let pipes = crate::spec::pipeline::build_pipelines(&g, 1, &spec.comm_resolutions, &ranks)?;
    let pipe_s = t2.elapsed().as_secs_f64();
    table.row(vec!["graph build".into(), fmt_s(build_s), format!("{} ops", g.ops.len())]);
    table.row(vec![
        "deduction+instantiation".into(),
        fmt_s(spec_s),
        format!("{} device graphs", spec.graphs.len()),
    ]);
    table.row(vec![
        "pipeline construction".into(),
        fmt_s(pipe_s),
        format!("{} pipelines", pipes.pipelines.len()),
    ]);
    table.row(vec![
        "NCCL group init (charged)".into(),
        fmt_s(elastic::HETU_GROUP_INIT_S),
        "constant; no real NCCL here".into(),
    ]);

    // Switching under the three planners.
    for (label, opts, fuse) in [
        ("switch: BSR w/o heuristics", BsrOptions { heuristics: false }, false),
        ("switch: unfused BSR", BsrOptions { heuristics: true }, false),
        ("switch: fused BSR", BsrOptions { heuristics: true }, true),
    ] {
        let t = std::time::Instant::now();
        // rank 31 just failed: it cannot source any shard
        let rep = plan_strategy_switch_avoiding(&c1, &c2, &cm, &cluster, opts, fuse, &[31])?;
        let plan_s = t.elapsed().as_secs_f64();
        table.row(vec![
            label.into(),
            fmt_s(rep.est_seconds),
            format!(
                "{} msgs, {} MB wire, planned in {}",
                rep.num_messages,
                rep.wire_bytes / (1 << 20),
                fmt_s(plan_s)
            ),
        ]);
    }
    Ok(table)
}

/// Table 2 — C1→C2 per-sender communication volumes (NVLink | IB), under
/// the unfused-no-heuristics planner vs the fused planner — plus an
/// **engine column**: the same transition lowered to tiny-48 and *executed*
/// on the native backend with the cluster topology threaded into the
/// planner, so the tabled engine volumes are measured wire traffic, not
/// simulation. Measured-vs-plan equality (total and per sender) is
/// asserted before the rows are emitted.
pub fn table2() -> Result<Table> {
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let c1 = stables::hetu_c1_32h20();
    let c2 = stables::hetu_c2_31h20();
    let mut table = Table::new(
        "Table 2 — C1→C2 send volumes per rank: NVLink MB | IB MB (engine rows: KiB, measured)",
        &["planner", "rank", "NVLink MB", "IB MB"],
    );
    for (label, opts, fuse) in [
        ("unfused w/o heuristics", BsrOptions { heuristics: false }, false),
        ("fused", BsrOptions { heuristics: true }, true),
    ] {
        // rank 31 just failed: its replicas source the moved shards
        let rep = plan_strategy_switch_avoiding(&c1, &c2, &cm, &cluster, opts, fuse, &[31])?;
        let vols = rep.plan.sender_volumes(&cluster);
        let mut ranks: Vec<u32> = vols.keys().copied().collect();
        ranks.sort_unstable();
        for r in ranks {
            let (nv, ib) = vols[&r];
            table.row(vec![
                label.into(),
                format!("R{r}"),
                (nv / (1 << 20)).to_string(),
                (ib / (1 << 20)).to_string(),
            ]);
        }
    }

    // ---- engine column: C1→C2 lowered and executed at real numerics.
    let tiny = crate::runtime::native::tiny_config();
    let lopts = crate::strategy::LowerOptions {
        total_microbatches: 4,
        tp_degrees: crate::runtime::native::TP_DEGREES.to_vec(),
    };
    let c1e = crate::strategy::lower(&c1, &tiny, &lopts)?;
    let c2e = crate::strategy::lower(&c2, &tiny, &lopts)?;
    let mut eng = crate::engine::Engine::with_runtime(
        crate::runtime::Runtime::native(tiny),
        c1e,
        42,
        1e-3,
    )?;
    eng.set_topology(cluster.clone());
    let report = eng.switch_to_avoiding(c2e, &[31])?;
    if report.wire_elems * 4 != report.plan.wire_bytes() {
        return Err(crate::Error::Engine(format!(
            "table2: engine measured {} wire bytes, plan predicts {}",
            report.wire_elems * 4,
            report.plan.wire_bytes()
        )));
    }
    let mut measured: std::collections::BTreeMap<u32, (u64, u64)> = std::collections::BTreeMap::new();
    for (&(from, to), &elems) in &report.sent {
        let e = measured.entry(from as u32).or_insert((0, 0));
        if crate::comm::Bandwidth::intra_node(&cluster, from as u32, to as u32) {
            e.0 += elems * 4;
        } else {
            e.1 += elems * 4;
        }
    }
    let planned = report.plan.sender_volumes(&cluster);
    for (r, vols) in &measured {
        if planned.get(r) != Some(vols) {
            return Err(crate::Error::Engine(format!(
                "table2: engine sender R{r} measured {vols:?} != planned {:?}",
                planned.get(r)
            )));
        }
    }
    for (r, (nv, ib)) in measured {
        table.row(vec![
            "engine tiny-48 (measured)".into(),
            format!("R{r}"),
            (nv / 1024).to_string(),
            (ib / 1024).to_string(),
        ]);
    }
    Ok(table)
}

/// Build a layer-level annotated graph for a set of strategies (shared by
/// Fig 18 and the specialization benches):
///
/// * per layer: a parameter + CommOp to the strategy's weight annotation
///   (Fig 9's CommOp id=1 — runs once, excluded from scheduling);
/// * per pipeline position: a TP-sync probe (placeholder `Partial` →
///   `Duplicate`, the per-microbatch AR that merges a TP group into one
///   stage) and a boundary CommOp to the next position (SR/BSR chaining
///   stages) — the scheduled comms §5.4's pipeline construction consumes.
pub fn build_strategy_graph(
    strategies: &[&ParallelStrategy],
) -> Result<(crate::graph::Graph, crate::graph::Binding)> {
    use crate::graph::{lits, DType, Graph};
    use crate::hspmd::{Annotation, DeviceGroup, DistStates, Subgroup};
    let layers = strategies
        .iter()
        .flat_map(|s| s.pipelines.iter().flat_map(|p| p.stages.iter().map(|st| st.layers.1)))
        .max()
        .unwrap_or(0);
    let mut g = Graph::new(strategies.len());
    let pl = crate::costmodel::ModelCfg::llama_32b().params_per_layer();
    for l in 0..layers {
        let anns: Vec<crate::hspmd::Annotation> = strategies
            .iter()
            .map(|s| s.weight_annotation(l, 0))
            .collect::<Result<_>>()?;
        let w = g.parameter(
            &format!("w{l}"),
            lits(&[pl]),
            DType::Bf16,
            vec![anns[0].clone(); strategies.len()],
        )?;
        let _wc = g.comm(w, anns)?;
    }

    // activation path: per pipeline position, one TP-sync probe + boundary
    let max_stages = strategies
        .iter()
        .flat_map(|s| s.pipelines.iter().map(|p| p.stages.len()))
        .max()
        .unwrap_or(1);
    let stage_ann = |k: usize, j: usize, partial: bool| -> Result<Annotation> {
        let s = strategies[k];
        let groups = s
            .pipelines
            .iter()
            .map(|p| {
                let st = &p.stages[j.min(p.stages.len() - 1)];
                let ds = if partial {
                    DistStates::partial(st.tp())
                } else {
                    DistStates::duplicate(st.tp())
                };
                Subgroup::new(DeviceGroup::new(st.ranks.clone())?, ds)
            })
            .collect::<Result<Vec<_>>>()?;
        // activations are batch-split across pipelines (hdim 0)
        Annotation::new(groups, if strategies[k].pipelines.len() > 1 { 0 } else { -1 })
    };
    let act_shape = lits(&[4096, 6400]);
    let mut prev: Option<crate::graph::TensorId> = None;
    for j in 0..max_stages {
        // TP-sync probe: Partial -> Duplicate within each stage subgroup
        let partials: Vec<Annotation> =
            (0..strategies.len()).map(|k| stage_ann(k, j, true)).collect::<Result<_>>()?;
        let dups: Vec<Annotation> =
            (0..strategies.len()).map(|k| stage_ann(k, j, false)).collect::<Result<_>>()?;
        let probe =
            g.placeholder(&format!("act_partial_{j}"), act_shape.clone(), DType::Bf16, partials)?;
        let synced = g.comm(probe, dups.clone())?;
        // boundary: chain from the previous position's synced activation
        if let Some(p) = prev {
            let _boundary = g.comm(p, dups)?;
        }
        prev = Some(synced);
    }
    Ok((g, crate::graph::Binding::new()))
}
