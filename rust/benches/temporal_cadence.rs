//! Bench: the temporal runtime — fig15-style *measured* engine cells
//! (amortized per-step time of each static strategy vs. the Hetu-A/B
//! switching engines over a synthetic CommonCrawl stream, executed as
//! real ragged windows), a ragged-dispatch cadence that *asserts* no
//! padded-context fallback path executes, plus the hot-switch cadence
//! micro: cold (plan + execute) vs. warm (plan-cache hit, per-sender
//! batched delivery) A↔B switch cycles.
//!
//! `--test` (the CI smoke mode) runs a 3-step stream, the ragged-cadence
//! assertions, and two switch cycles, proving the subsystem executes
//! end-to-end.

use hetu::coordinator::SyntheticCorpus;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::data::StepBatch;
use hetu::engine::ExecMode;
use hetu::metrics::benchjson::BenchReport;
use hetu::runtime::{native, Runtime};
use hetu::temporal::{default_pool_entries, DispatchPolicy, Dispatcher, StrategyPool};

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let steps: usize = if smoke {
        3
    } else {
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20)
    };
    let mut bj = BenchReport::new("temporal", smoke);
    bj.tag("backend", "native").tag("steps_per_cell", &steps.to_string());
    let t0 = std::time::Instant::now();
    let table = hetu::figures::fig15_engine(steps).expect("fig15_engine");
    println!("{}", table.markdown());
    let fig15_s = t0.elapsed().as_secs_f64();
    bj.row("fig15 measured engine cells (stream)", "wall", fig15_s, fig15_s);

    // ragged-dispatch cadence: drive Hetu-B over a short/long/short
    // cadence and assert the engine executed the batches' real packed
    // windows — hot switches fire, every step carries measured ragged
    // tokens, and NO padded-context fallback position executes
    let tiny = native::tiny_config();
    let mk = |lens: Vec<u64>| {
        let total_tokens = lens.iter().sum();
        StepBatch { seq_lens: lens, total_tokens }
    };
    let mut long = vec![2048u64; 20];
    long.push(20_000);
    let cadence = vec![mk(vec![2048; 24]), mk(long), mk(vec![2048; 24])];
    let mut rpool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut reng = rpool.spawn_engine(Runtime::native(tiny), 0, 7, 1e-3).unwrap();
    let mut disp = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
    // cell scaling derived from the pool's widest context, not the 32K
    // constant (ROADMAP ragged follow-on; identical for the default pool)
    disp.scale_cells_to_pool(&rpool, tiny.seq);
    let mut rcorpus = SyntheticCorpus::new(3, tiny.vocab);
    let tr = std::time::Instant::now();
    let rep = disp.run_stream(&mut reng, &mut rpool, &cadence, &mut rcorpus).expect("ragged cadence");
    let stream_s = tr.elapsed().as_secs_f64();
    bj.row("ragged cadence run_stream (3 batches)", "wall", stream_s, stream_s);
    assert!(rep.switches >= 2, "cadence must hot-switch, got {}", rep.switches);
    assert!(
        rep.steps.iter().all(|s| s.windows > 0 && s.tokens > 0),
        "every step must execute measured ragged windows"
    );
    assert_eq!(
        rep.total_padded(),
        0,
        "no padded-context fallback path may execute on dispatched windows"
    );
    // §7 measured interleave (the CI smoke's overlap contract): every
    // step's exposed switch time is *measured* by the event-driven
    // executor — the switch's per-sender delivery batches ride wire lanes
    // inside the first post-switch step — and can never exceed the old
    // accounted max(0, Σ delivery − makespan) bookkeeping
    for s in &rep.steps {
        assert!(
            s.exposed_s <= s.exposed_bound_s + 1e-9,
            "step {}: measured exposure {} exceeds the accounted bound {}",
            s.step,
            s.exposed_s,
            s.exposed_bound_s
        );
    }
    let measured: f64 = rep.steps.iter().map(|s| s.exposed_s).sum();
    let bound: f64 = rep.steps.iter().map(|s| s.exposed_bound_s).sum();
    // exposure comes out of the event-driven executor's replayed lanes —
    // a modeled quantity, not a wall-clock one
    bj.row("ragged cadence exposed switch (replay)", "modeled", measured, measured);
    bj.row("ragged cadence exposed bound (account)", "modeled", bound, bound);
    println!(
        "ragged cadence: {} steps, {} switches, {} windows, {} engine tokens, 0 padded, \
         measured exposed {:.3} ms (accounted bound {:.3} ms)",
        rep.steps.len(),
        rep.switches,
        rep.total_windows(),
        rep.total_tokens(),
        measured * 1e3,
        bound * 1e3
    );

    // ---- heavy-tail cadence stress (ROADMAP item 5 leftover): drive
    // Hetu-B over the GitHubHeavyTail mixture — a GitHub log-normal body
    // with a 5% Pareto tail that pins sequences at the context limit —
    // so the 5% hysteresis default has a measured stress case on record.
    // The tail flips the batch's max length step to step, which is
    // exactly the regime hysteresis exists to damp: the report records
    // how often Hetu-B actually switched under it.
    let mut hrng = hetu::testutil::Rng::new(17);
    let hsteps = if smoke { 3 } else { 12 };
    let hcadence: Vec<StepBatch> = (0..hsteps)
        .map(|_| {
            hetu::data::sample_step(&mut hrng, hetu::data::Corpus::GitHubHeavyTail, 49_152, 32_768)
        })
        .collect();
    let mut hpool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut heng = hpool.spawn_engine(Runtime::native(tiny), 0, 7, 1e-3).unwrap();
    let mut hdisp = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
    hdisp.scale_cells_to_pool(&hpool, tiny.seq);
    let mut hcorpus = SyntheticCorpus::new(5, tiny.vocab);
    let hrep = hdisp
        .run_stream(&mut heng, &mut hpool, &hcadence, &mut hcorpus)
        .expect("heavy-tail cadence");
    assert_eq!(hrep.total_padded(), 0, "heavy-tail windows must execute ragged, not padded");
    assert!(
        hrep.steps.iter().all(|s| s.windows > 0 && s.tokens > 0),
        "every heavy-tail step must execute measured windows"
    );
    let h_amt = hrep.amortized_step_s();
    bj.row("heavy-tail cadence amortized step (Hetu-B)", "modeled", h_amt, h_amt);
    let h_sw = hrep.switches as f64;
    bj.row("heavy-tail cadence switches (5% hysteresis)", "modeled", h_sw, h_sw);
    println!(
        "heavy-tail cadence: {} steps, {} switches under 5% hysteresis, {} windows, \
         amortized {:.3} ms/step",
        hrep.steps.len(),
        hrep.switches,
        hrep.total_windows(),
        h_amt * 1e3
    );

    // switch cadence: repeated short↔long transitions through the cache
    let tiny = native::tiny_config();
    let mut pool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut eng = pool.spawn_engine(Runtime::native(tiny), 0, 42, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(9, tiny.vocab);
    let (b, s) = (tiny.batch, tiny.seq);
    eng.train_step(&mut |_p, _m| corpus.microbatch(b, s)).unwrap(); // moments exist
    let cycles = if smoke { 2 } else { 50 };
    let mut cold = 0.0f64;
    let mut warm = 0.0f64;
    for c in 0..cycles {
        let t = std::time::Instant::now();
        pool.switch_engine(&mut eng, 2).unwrap();
        pool.switch_engine(&mut eng, 0).unwrap();
        let dt = t.elapsed().as_secs_f64();
        if c == 0 {
            cold = dt;
        } else {
            warm += dt;
        }
    }
    let warm_cycle = warm / (cycles - 1) as f64;
    println!(
        "hot-switch short<->long: cold (plan+exec) {:.3} ms/cycle, warm (cached) {:.3} ms/cycle, plan cache {} hits / {} misses",
        cold * 1e3,
        warm_cycle * 1e3,
        pool.hits(),
        pool.misses()
    );
    bj.row("hot-switch cycle cold (plan+exec)", "wall", cold, cold);
    bj.row("hot-switch cycle warm (cached)", "wall", warm_cycle, warm_cycle);

    // compiled-artifact cadence: a Compiled-mode engine rides the same
    // short↔long switch cadence, re-acquiring its frozen tape from the
    // pool's artifact cache before every step. Each entry compiles
    // exactly once (the first lap); every later lookup is an Arc handout,
    // so the amortized row is pure dispatch + cache hit — the cost the
    // compile pass was built to pull off the steady-state path.
    let mut cpool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut ceng = cpool.spawn_engine_compiled(Runtime::native(tiny), 0, 42, 1e-3).unwrap();
    let mut ccorpus = SyntheticCorpus::new(31, tiny.vocab);
    let ccycles: usize = if smoke { 2 } else { 50 };
    let mut warm_steps = 0.0f64;
    let mut counted = 0u32;
    for c in 0..ccycles {
        for &next in &[2usize, 0] {
            cpool.compiled_for(&mut ceng).unwrap();
            let t = std::time::Instant::now();
            ceng.train_step(&mut |_p, _m| ccorpus.microbatch(b, s)).unwrap();
            if c > 0 {
                warm_steps += t.elapsed().as_secs_f64();
                counted += 1;
            }
            cpool.switch_engine(&mut ceng, next).unwrap();
        }
    }
    let amortized = warm_steps / f64::from(counted);
    assert_eq!(cpool.artifact_misses(), 2, "A<->B cadence compiles each entry exactly once");
    assert_eq!(
        cpool.artifact_hits(),
        (2 * ccycles - 2) as u64,
        "every lookup after the first lap must hit the artifact cache"
    );
    println!(
        "compiled cadence: amortized warm step {:.3} ms/step over {} steps (artifact cache {} hits / {} misses)",
        amortized * 1e3,
        counted,
        cpool.artifact_hits(),
        cpool.artifact_misses()
    );
    bj.row("compiled cadence amortized step (cached)", "wall", amortized, amortized);

    // ---- §10 measured per-step breakdowns: the same cadence on the
    // threaded executor with tracing on. Spans carry real wall timestamps
    // from the step epoch, so the compute/comm/bubble/switch columns are
    // measured wall seconds and the rows are honestly labelled `wall`.
    let mut tpool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut teng = tpool.spawn_engine(Runtime::native(tiny), 0, 7, 1e-3).unwrap();
    teng.set_exec_mode(ExecMode::Threaded);
    teng.set_tracing(true);
    let mut tcorpus = SyntheticCorpus::new(3, tiny.vocab);
    let trep =
        disp.run_stream(&mut teng, &mut tpool, &cadence, &mut tcorpus).expect("traced cadence");
    for st in &trep.steps {
        let bd = st.breakdown.expect("traced step must carry a breakdown");
        let sum = bd.components_sum_s();
        assert!(
            (sum - st.makespan_s).abs() <= 0.05 * st.makespan_s.max(1e-12),
            "step {}: breakdown components ({sum}s) must sum to the wall makespan ({}s) \
             within 5%",
            st.step,
            st.makespan_s
        );
        bj.row_cols(
            &format!("cadence step {} breakdown", st.step),
            "wall",
            st.makespan_s,
            st.makespan_s,
            &[
                ("compute_s", bd.compute_s),
                ("comm_s", bd.comm_s),
                ("optim_s", bd.optim_s),
                ("bubble_s", bd.bubble_s),
                ("switch_s", bd.switch_s),
            ],
        );
        println!(
            "cadence step {} [wall]: compute {:.3} ms, comm {:.3} ms, optim {:.3} ms, \
             bubble {:.3} ms, switch {:.3} ms (makespan {:.3} ms)",
            st.step,
            bd.compute_s * 1e3,
            bd.comm_s * 1e3,
            bd.optim_s * 1e3,
            bd.bubble_s * 1e3,
            bd.switch_s * 1e3,
            st.makespan_s * 1e3
        );
    }

    // ---- §10 span-calibrated dispatch: fit a measured (s/flop, s/byte)
    // profile from one traced step, rerun the cadence under the
    // calibrated Hetu-B scorer, and require it not to lose to the
    // analytic scorer on the same (modeled-clock) stream.
    let mut kpool = StrategyPool::new(tiny, default_pool_entries(&tiny).unwrap()).unwrap();
    let mut keng = kpool.spawn_engine(Runtime::native(tiny), 0, 7, 1e-3).unwrap();
    let mut kcorpus = SyntheticCorpus::new(3, tiny.vocab);
    let mut kdisp = Dispatcher::new(CostModel::new(ModelCfg::llama_32b()), DispatchPolicy::HetuB);
    kdisp.scale_cells_to_pool(&kpool, tiny.seq);
    let prof = kdisp
        .calibrate_from_step(&mut keng, &kpool, &cadence[0], &mut kcorpus)
        .expect("calibrate_from_step");
    let krep = kdisp
        .run_stream(&mut keng, &mut kpool, &cadence, &mut kcorpus)
        .expect("calibrated cadence");
    let (analytic_amt, calibrated_amt) = (rep.amortized_step_s(), krep.amortized_step_s());
    bj.row("cadence amortized step (analytic Hetu-B)", "modeled", analytic_amt, analytic_amt);
    bj.row("cadence amortized step (calibrated Hetu-B)", "modeled", calibrated_amt, calibrated_amt);
    println!(
        "span-calibrated dispatch: s/flop {:.3e}, s/byte {:.3e} -> amortized {:.3} ms vs \
         analytic {:.3} ms",
        prof.s_per_flop,
        prof.s_per_byte,
        calibrated_amt * 1e3,
        analytic_amt * 1e3
    );
    if !smoke {
        assert!(
            calibrated_amt <= analytic_amt * 1.05,
            "calibrated Hetu-B amortized step ({calibrated_amt}s) must reproduce or improve \
             the analytic scorer ({analytic_amt}s)"
        );
    }

    println!("\n({steps} steps/cell, generated in {:.1}s)", t0.elapsed().as_secs_f64());
    let path = bj.write().expect("write BENCH_temporal.json");
    println!("wrote {}", path.display());
}
