//! Bench: regenerate Fig 15 (mixed-length per-step time distributions for
//! DeepSpeed / Megatron / HotSPa / Hetu-A / Hetu-B over CommonCrawl- and
//! GitHub-like workloads at 32K and 16K context).
//!
//! The cells are **simulated** step times (cost-model replay), so every
//! emitted row is tagged `modeled` in `BENCH_fig15.json`.

use hetu::metrics::benchjson::BenchReport;

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(30);
    let t0 = std::time::Instant::now();
    let (table, cells) = hetu::figures::fig15(steps).expect("fig15");
    println!("{}", table.markdown());
    let mut bj = BenchReport::new("fig15", steps <= 3);
    bj.tag("steps_per_cell", &steps.to_string());
    let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
    for c in &cells {
        for (sys, samples) in &c.samples {
            let best = samples.iter().cloned().fold(f64::INFINITY, f64::min);
            bj.row(&format!("{} / {sys}", c.label), "modeled", mean(samples), best);
        }
        let hetu_b = c.samples.iter().find(|(s, _)| *s == "Hetu-B").unwrap();
        let hotspa = c.samples.iter().find(|(s, _)| *s == "HotSPa").unwrap();
        println!(
            "  {}: Hetu-B {:.2}s vs HotSPa {:.2}s [{}]",
            c.label,
            mean(&hetu_b.1),
            mean(&hotspa.1),
            if mean(&hetu_b.1) <= mean(&hotspa.1) * 1.02 { "ok" } else { "VIOLATION" }
        );
    }
    println!("\n({} steps/cell, generated in {:.1}s)", steps, t0.elapsed().as_secs_f64());
    let path = bj.write().expect("write BENCH_fig15.json");
    println!("wrote {}", path.display());
}
