//! Bench: L3 hot-path micro-benchmarks (the §Perf targets) — BSR planning,
//! fused transition planning, annotation deduction, full specialization of
//! a 48-rank 60-layer graph, the discrete-event simulator, strategy
//! lowering, the native GEMM kernels, and the real-numerics engine step
//! (native backend) under both schedules.
//!
//! `--test` (the CI smoke mode) runs every row once, just proving the
//! harness executes.

use hetu::cluster::Cluster;
use hetu::comm::BsrOptions;
use hetu::coordinator::SyntheticCorpus;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::engine::{Engine, EngineStrategy, ShardLayout, BLOCK_PARAMS};
use hetu::metrics::bench;
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::ScheduleKind;
use hetu::strategy::{tables, LowerOptions};

fn report(name: &str, iters: u32, f: impl FnMut()) {
    let (mean, best) = bench(iters, f);
    println!("{name:<44} mean {:>10.3}ms   best {:>10.3}ms", mean * 1e3, best * 1e3);
}

/// The seed engine's per-step sync-group rebuild (`BTreeMap` over
/// `(layer, param, shard)` re-derived from the strategy on every call) —
/// the *before* baseline for the ShardLayout rows below.
fn legacy_sync_group_rebuild(strategy: &EngineStrategy) -> usize {
    let mut groups: std::collections::BTreeMap<(u32, &str, usize), Vec<usize>> =
        std::collections::BTreeMap::new();
    for p in &strategy.pipelines {
        for s in &p.stages {
            for l in s.layers.0..s.layers.1 {
                for (j, &d) in s.devices.iter().enumerate() {
                    for p_name in BLOCK_PARAMS {
                        groups.entry((l, p_name, j)).or_default().push(d);
                    }
                }
            }
        }
    }
    groups.len()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let it = |n: u32| if smoke { 1 } else { n };

    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let c1 = tables::hetu_c1_32h20();
    let c2 = tables::hetu_c2_31h20();
    let hetero = Cluster::h800_16_h20_32();
    let big = tables::hetu_32b_16h800_32h20();

    report("simulate_step C1 (32 ranks, 60 layers)", it(50), || {
        std::hint::black_box(hetu::sim::simulate_step(&cluster, &cm, &c1).unwrap());
    });
    report("simulate_step hetero 48-rank strategy", it(50), || {
        std::hint::black_box(hetu::sim::simulate_step(&hetero, &cm, &big).unwrap());
    });
    report("plan_strategy_switch C1->C2 (fused)", it(20), || {
        std::hint::black_box(
            hetu::switch::plan_strategy_switch(&c1, &c2, &cm, &cluster, BsrOptions::default(), true)
                .unwrap(),
        );
    });
    report("plan_strategy_switch C1->C2 (unfused)", it(20), || {
        std::hint::black_box(
            hetu::switch::plan_strategy_switch(&c1, &c2, &cm, &cluster, BsrOptions::default(), false)
                .unwrap(),
        );
    });

    // full specialization pipeline on a 60-layer two-strategy graph
    report("specialize 60-layer graph (deduce+resolve)", it(20), || {
        let (mut g, binding) = hetu::figures::build_strategy_graph(&[&c1, &c2]).unwrap();
        let spec = hetu::spec::instantiate::specialize(
            &mut g,
            1,
            &binding,
            &cluster,
            BsrOptions::default(),
        )
        .unwrap();
        std::hint::black_box(spec.graphs.len());
    });

    // deduction-only over a wide graph
    report("deduce 60-layer graph", it(50), || {
        let (mut g, _) = hetu::figures::build_strategy_graph(&[&c1, &c2]).unwrap();
        hetu::graph::deduce::deduce(&mut g, 0).unwrap();
        std::hint::black_box(g.ops.len());
    });

    // Hetu-B per-step planning (dispatch + sim)
    let mut rng = hetu::testutil::Rng::new(1);
    let batch = hetu::data::sample_step(&mut rng, hetu::data::Corpus::CommonCrawl, 200_000, 32768);
    report("hetu_b_step (dispatch + sim)", it(20), || {
        std::hint::black_box(hetu::figures::hetu_b_step(&cluster, &cm, &batch, 32768).unwrap());
    });

    // strategy lowering: Table-row encodings -> runnable EngineStrategy
    let tiny = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] };
    report("lower C2 encoding -> EngineStrategy", it(500), || {
        std::hint::black_box(hetu::strategy::lower(&c2, &tiny, &lopts).unwrap().num_devices());
    });

    // native GEMM kernels (the blocked-matmul guard: the head GEMM
    // dominates the tiny-48 step, so this row tracks the debug-mode
    // <100 ms step budget at release granularity)
    let a: Vec<f32> = (0..32 * 48).map(|i| (i % 7) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..48 * 512).map(|i| (i % 5) as f32 * 0.1).collect();
    report("native matmul 32x48x512 (head shape)", it(2000), || {
        std::hint::black_box(native::matmul(&a, &b, 32, 48, 512));
    });

    // ---- engine-step micro (the §Perf target of the layout refactor).
    // Before: `sync_gradients` re-derived its BTreeMap groups + scanned
    // every device key each step; after: the plan is read from the cached
    // ShardLayout. The two "sync-group" rows isolate that cost — the
    // layout builds once per strategy, the legacy rebuild ran every step.
    let strat = EngineStrategy::uniform("dp2tp2", 2, 2, 1, tiny.layers, 1);
    report("sync-group legacy rebuild (per step)", it(500), || {
        std::hint::black_box(legacy_sync_group_rebuild(&strat));
    });
    report("sync-group ShardLayout build (per switch)", it(500), || {
        std::hint::black_box(ShardLayout::build(&tiny, &strat).unwrap().sync_ops.len());
    });
    let mut eng =
        Engine::with_runtime(Runtime::native(tiny), strat, 42, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(7, tiny.vocab);
    let (b_sz, s_sz) = (tiny.batch, tiny.seq);
    report("engine train_step dp2tp2 (native tiny-48)", it(10), || {
        std::hint::black_box(
            eng.train_step(&mut |_p, _m| corpus.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });

    // the same step under 1F1B through the unified schedule interpreter
    let strat_1f1b = EngineStrategy::uniform("pp2x4mb", 1, 1, 2, tiny.layers, 4)
        .with_schedule(ScheduleKind::OneFOneB);
    let mut eng2 = Engine::with_runtime(Runtime::native(tiny), strat_1f1b, 42, 1e-3).unwrap();
    let mut corpus2 = SyntheticCorpus::new(8, tiny.vocab);
    report("engine train_step pp2 1F1B (native tiny-48)", it(10), || {
        std::hint::black_box(
            eng2.train_step(&mut |_p, _m| corpus2.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });

    // ---- §6 temporal runtime. `plan_switch` is the pairwise planning
    // cost the pool's cache amortizes away; the hot-switch row executes a
    // cached plan with per-sender batched delivery (sources resolved once
    // per (sender, tensor) — the switch.rs serialization fix this bench
    // guards). Moments ride along in both rows.
    let la = ShardLayout::build(&tiny, &EngineStrategy::uniform("dp2", 2, 1, 1, tiny.layers, 1))
        .unwrap();
    let lb = ShardLayout::build(&tiny, &EngineStrategy::uniform("tp2", 1, 2, 1, tiny.layers, 2))
        .unwrap();
    report("plan_switch dp2->tp2 (uncached, +moments)", it(100), || {
        std::hint::black_box(
            hetu::engine::plan_switch(&tiny, &la, &lb, true, &hetu::comm::UniformBandwidth, &[])
                .unwrap()
                .plan
                .num_messages(),
        );
    });
    let mut pool = hetu::temporal::StrategyPool::new(
        tiny,
        vec![
            (EngineStrategy::uniform("dp2", 2, 1, 1, tiny.layers, 1), 4096),
            (EngineStrategy::uniform("tp2", 1, 2, 1, tiny.layers, 2), 32768),
        ],
    )
    .unwrap();
    let mut eng3 = pool.spawn_engine(Runtime::native(tiny), 0, 42, 1e-3).unwrap();
    let mut corpus3 = SyntheticCorpus::new(11, tiny.vocab);
    eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
    report("engine hot-switch A<->B (cached, batched)", it(20), || {
        pool.switch_engine(&mut eng3, 1).unwrap();
        std::hint::black_box(pool.switch_engine(&mut eng3, 0).unwrap().wire_elems);
    });
    // cache hits are allocation-free: plan_for hands out the pooled
    // Arc<SwitchPlan> by refcount — no FusedBsrPlan/ShardLayout clones on
    // the steady-state switch path (the hot-switch constant-factor fix
    // this row guards; both keys are warm after the cycles above)
    report("pool plan_for cache hit (Arc handout)", it(5000), || {
        std::hint::black_box(
            pool.plan_for(0, 1, true, false, &hetu::comm::UniformBandwidth)
                .unwrap()
                .plan
                .num_messages(),
        );
    });

    // ragged engine step: the dispatcher's real packed windows (6 × [2,2]
    // micro-batches per DP pipeline) vs the fixed-shape row above — the
    // variable-shape executor path the temporal runtime drives
    let ragged_strat = EngineStrategy::uniform("dp2-ragged", 2, 1, 1, tiny.layers, 1);
    let mut eng4 = Engine::with_runtime(Runtime::native(tiny), ragged_strat, 42, 1e-3).unwrap();
    let windows: Vec<Vec<hetu::engine::WindowShape>> = (0..2)
        .map(|_| {
            (0..6).map(|_| hetu::engine::WindowShape { rows: vec![2, 2], seq_len: 2 }).collect()
        })
        .collect();
    eng4.set_microbatches(&windows).unwrap();
    let mut corpus4 = SyntheticCorpus::new(13, tiny.vocab);
    report("engine train_step dp2 ragged 12x[2,2]", it(10), || {
        std::hint::black_box(
            eng4.train_step(&mut |p, m| corpus4.window_for(&windows[p][m])).unwrap().loss,
        );
    });

    // ---- §7 progressive per-rank specialization. The lowering pass runs
    // once per (strategy, micro-batch counts) and on every switch — this
    // row is that re-specialization cost on the lowered C2 hetero
    // encoding (2 uneven pipelines, TP tail, both schedule groups).
    let c2e = hetu::strategy::lower(&c2, &tiny, &lopts).unwrap();
    let c2_layout = ShardLayout::build(&tiny, &c2e).unwrap();
    report("specialize lowered-C2 -> per-rank plans", it(500), || {
        std::hint::black_box(
            hetu::engine::specialize(&c2e, &c2_layout, false).unwrap().len(),
        );
    });

    // the interleaved post-switch step: a cached hot switch queues its
    // per-sender delivery batches, and the next step's executor rides
    // them on wire lanes concurrent with compute (§6.2 measured
    // interleave) — switch + first-step cost as one unit
    report("hot-switch + interleaved first step", it(10), || {
        pool.switch_engine(&mut eng3, 1).unwrap();
        let a = eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
        pool.switch_engine(&mut eng3, 0).unwrap();
        let b = eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
        assert!(
            a.exposed_switch_s <= a.switch_delivery_s && b.exposed_switch_s <= b.switch_delivery_s,
            "exposure is the non-overlapped remainder of the delivery"
        );
        std::hint::black_box(a.exposed_switch_s + b.exposed_switch_s);
    });
}
