//! Bench: L3 hot-path micro-benchmarks (the §Perf targets) — BSR planning,
//! fused transition planning, annotation deduction, full specialization of
//! a 48-rank 60-layer graph, the discrete-event simulator, strategy
//! lowering, the native GEMM/attention kernels, and the real-numerics
//! engine step (native backend) under both schedules and both executors.
//!
//! Every row is labelled `[wall]` (measured wall-clock) or `[modeled]`
//! (replayed cost-model/simulator estimate) — the two are different
//! quantities and must never be read as one. The event-driven executor's
//! `StepStats::makespan_s` is a *replay* of modeled task durations; only
//! the threaded executor's makespan is wall-clock.
//!
//! The run is also emitted as `BENCH_hotpath.json` (git rev + config +
//! rows) for `tools/bench_compare.py`.
//!
//! `--test` (the CI smoke mode) runs every row once, just proving the
//! harness executes.

use hetu::cluster::Cluster;
use hetu::comm::BsrOptions;
use hetu::coordinator::SyntheticCorpus;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::engine::{Engine, EngineStrategy, ExecMode, ShardLayout, BLOCK_PARAMS};
use hetu::metrics::bench;
use hetu::metrics::benchjson::BenchReport;
use hetu::runtime::{native, Runtime};
use hetu::spec::schedule::ScheduleKind;
use hetu::strategy::{tables, LowerOptions};

fn report(rep: &mut BenchReport, name: &str, kind: &str, iters: u32, f: impl FnMut()) {
    let (mean, best) = bench(iters, f);
    println!("{name:<44} [{kind:>7}] mean {:>10.3}ms   best {:>10.3}ms", mean * 1e3, best * 1e3);
    rep.row(name, kind, mean, best);
}

/// The seed engine's per-step sync-group rebuild (`BTreeMap` over
/// `(layer, param, shard)` re-derived from the strategy on every call) —
/// the *before* baseline for the ShardLayout rows below.
fn legacy_sync_group_rebuild(strategy: &EngineStrategy) -> usize {
    let mut groups: std::collections::BTreeMap<(u32, &str, usize), Vec<usize>> =
        std::collections::BTreeMap::new();
    for p in &strategy.pipelines {
        for s in &p.stages {
            for l in s.layers.0..s.layers.1 {
                for (j, &d) in s.devices.iter().enumerate() {
                    for p_name in BLOCK_PARAMS {
                        groups.entry((l, p_name, j)).or_default().push(d);
                    }
                }
            }
        }
    }
    groups.len()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--test");
    let it = |n: u32| if smoke { 1 } else { n };
    let mut rep = BenchReport::new("hotpath", smoke);
    rep.tag("backend", "native")
        .tag("model", "tiny-48")
        .tag("build", if cfg!(debug_assertions) { "debug" } else { "release" });
    let rep = &mut rep;

    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let c1 = tables::hetu_c1_32h20();
    let c2 = tables::hetu_c2_31h20();
    let hetero = Cluster::h800_16_h20_32();
    let big = tables::hetu_32b_16h800_32h20();

    report(rep, "simulate_step C1 (32 ranks, 60 layers)", "wall", it(50), || {
        std::hint::black_box(hetu::sim::simulate_step(&cluster, &cm, &c1).unwrap());
    });
    report(rep, "simulate_step hetero 48-rank strategy", "wall", it(50), || {
        std::hint::black_box(hetu::sim::simulate_step(&hetero, &cm, &big).unwrap());
    });
    report(rep, "plan_strategy_switch C1->C2 (fused)", "wall", it(20), || {
        std::hint::black_box(
            hetu::switch::plan_strategy_switch(&c1, &c2, &cm, &cluster, BsrOptions::default(), true)
                .unwrap(),
        );
    });
    report(rep, "plan_strategy_switch C1->C2 (unfused)", "wall", it(20), || {
        std::hint::black_box(
            hetu::switch::plan_strategy_switch(
                &c1,
                &c2,
                &cm,
                &cluster,
                BsrOptions::default(),
                false,
            )
            .unwrap(),
        );
    });

    // full specialization pipeline on a 60-layer two-strategy graph
    report(rep, "specialize 60-layer graph (deduce+resolve)", "wall", it(20), || {
        let (mut g, binding) = hetu::figures::build_strategy_graph(&[&c1, &c2]).unwrap();
        let spec = hetu::spec::instantiate::specialize(
            &mut g,
            1,
            &binding,
            &cluster,
            BsrOptions::default(),
        )
        .unwrap();
        std::hint::black_box(spec.graphs.len());
    });

    // deduction-only over a wide graph
    report(rep, "deduce 60-layer graph", "wall", it(50), || {
        let (mut g, _) = hetu::figures::build_strategy_graph(&[&c1, &c2]).unwrap();
        hetu::graph::deduce::deduce(&mut g, 0).unwrap();
        std::hint::black_box(g.ops.len());
    });

    // Hetu-B per-step planning (dispatch + sim)
    let mut rng = hetu::testutil::Rng::new(1);
    let batch = hetu::data::sample_step(&mut rng, hetu::data::Corpus::CommonCrawl, 200_000, 32768);
    report(rep, "hetu_b_step (dispatch + sim)", "wall", it(20), || {
        std::hint::black_box(hetu::figures::hetu_b_step(&cluster, &cm, &batch, 32768).unwrap());
    });

    // strategy lowering: Table-row encodings -> runnable EngineStrategy
    let tiny = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] };
    report(rep, "lower C2 encoding -> EngineStrategy", "wall", it(500), || {
        std::hint::black_box(hetu::strategy::lower(&c2, &tiny, &lopts).unwrap().num_devices());
    });

    // native GEMM kernels (the blocked-matmul guard: the head GEMM
    // dominates the tiny-48 step, so this row tracks the debug-mode
    // <100 ms step budget at release granularity)
    let a: Vec<f32> = (0..32 * 48).map(|i| (i % 7) as f32 * 0.1).collect();
    let b: Vec<f32> = (0..48 * 512).map(|i| (i % 5) as f32 * 0.1).collect();
    report(rep, "native matmul 32x48x512 (head shape)", "wall", it(2000), || {
        std::hint::black_box(native::matmul(&a, &b, 32, 48, 512));
    });
    // bf16-storage / f32-accumulate tier on the same shape
    let a16: Vec<u16> = a.iter().map(|&x| native::f32_to_bf16(x)).collect();
    let b16: Vec<u16> = b.iter().map(|&x| native::f32_to_bf16(x)).collect();
    report(rep, "native matmul_bf16 32x48x512 (bf16 tier)", "wall", it(2000), || {
        std::hint::black_box(native::matmul_bf16(&a16, &b16, 32, 48, 512));
    });
    // tiled (flash-style) attention on the tiny-48 block shape
    let (ab, asq, anh, ahd) = (tiny.batch, tiny.seq, tiny.heads, tiny.hidden / tiny.heads);
    let qkv: Vec<f32> = (0..ab * asq * anh * ahd).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect();
    report(rep, "native flash attention fwd (tiny-48 qkv)", "wall", it(2000), || {
        std::hint::black_box(native::attention(&qkv, &qkv, &qkv, ab, asq, anh, ahd));
    });

    // ---- engine-step micro (the §Perf target of the layout refactor).
    // Before: `sync_gradients` re-derived its BTreeMap groups + scanned
    // every device key each step; after: the plan is read from the cached
    // ShardLayout. The two "sync-group" rows isolate that cost — the
    // layout builds once per strategy, the legacy rebuild ran every step.
    let strat = EngineStrategy::uniform("dp2tp2", 2, 2, 1, tiny.layers, 1);
    report(rep, "sync-group legacy rebuild (per step)", "wall", it(500), || {
        std::hint::black_box(legacy_sync_group_rebuild(&strat));
    });
    report(rep, "sync-group ShardLayout build (per switch)", "wall", it(500), || {
        std::hint::black_box(ShardLayout::build(&tiny, &strat).unwrap().sync_ops.len());
    });
    let mut eng = Engine::with_runtime(Runtime::native(tiny), strat, 42, 1e-3).unwrap();
    let mut corpus = SyntheticCorpus::new(7, tiny.vocab);
    let (b_sz, s_sz) = (tiny.batch, tiny.seq);
    report(rep, "engine train_step dp2tp2 (native tiny-48)", "wall", it(10), || {
        std::hint::black_box(
            eng.train_step(&mut |_p, _m| corpus.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });

    // the same step under 1F1B through the unified schedule interpreter
    let strat_1f1b = EngineStrategy::uniform("pp2x4mb", 1, 1, 2, tiny.layers, 4)
        .with_schedule(ScheduleKind::OneFOneB);
    let mut eng2 = Engine::with_runtime(Runtime::native(tiny), strat_1f1b, 42, 1e-3).unwrap();
    let mut corpus2 = SyntheticCorpus::new(8, tiny.vocab);
    report(rep, "engine train_step pp2 1F1B (native tiny-48)", "wall", it(10), || {
        std::hint::black_box(
            eng2.train_step(&mut |_p, _m| corpus2.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });

    // ---- concurrent executor (OS threads). A multi-pipeline strategy
    // (dp2tp2: 2 pipelines x TP2) stepped by both executors on identical
    // same-seed batch streams. The event-driven makespan is a *modeled
    // replay*; only the threaded row's step time is real wall-clock
    // parallelism. The paired warm-up step asserts the deterministic-
    // reduction contract: threaded loss is bit-identical.
    let strat_c = EngineStrategy::uniform("dp2tp2", 2, 2, 1, tiny.layers, 2);
    let mut eng_ev =
        Engine::with_runtime(Runtime::native(tiny), strat_c.clone(), 42, 1e-3).unwrap();
    let mut eng_th = Engine::with_runtime(Runtime::native(tiny), strat_c, 42, 1e-3).unwrap();
    eng_th.set_exec_mode(ExecMode::Threaded);
    let mut corpus_ev = SyntheticCorpus::new(21, tiny.vocab);
    let mut corpus_th = SyntheticCorpus::new(21, tiny.vocab);
    let st_ev = eng_ev.train_step(&mut |_p, _m| corpus_ev.microbatch(b_sz, s_sz)).unwrap();
    let st_th = eng_th.train_step(&mut |_p, _m| corpus_th.microbatch(b_sz, s_sz)).unwrap();
    assert_eq!(
        st_ev.loss.to_bits(),
        st_th.loss.to_bits(),
        "threaded loss must be bit-identical to the event-driven executor"
    );
    rep.row(
        "step makespan dp2tp2 (replayed estimate)",
        "modeled",
        st_ev.makespan_s,
        st_ev.makespan_s,
    );
    println!(
        "{:<44} [modeled] {:>15.3}ms   (cost-model replay, not a measurement)",
        "step makespan dp2tp2 (replayed estimate)",
        st_ev.makespan_s * 1e3
    );
    report(rep, "step wall dp2tp2 single-thread executor", "wall", it(10), || {
        std::hint::black_box(
            eng_ev.train_step(&mut |_p, _m| corpus_ev.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });
    report(rep, "step wall dp2tp2 threaded executor", "wall", it(10), || {
        std::hint::black_box(
            eng_th.train_step(&mut |_p, _m| corpus_th.microbatch(b_sz, s_sz)).unwrap().loss,
        );
    });
    let (ev, th) = (rep.rows[rep.rows.len() - 2].best_s, rep.rows[rep.rows.len() - 1].best_s);
    println!(
        "    threaded vs single-thread wall (best): {:.3}ms vs {:.3}ms ({:.2}x)",
        th * 1e3,
        ev * 1e3,
        ev / th.max(1e-12)
    );

    // ---- §6 temporal runtime. `plan_switch` is the pairwise planning
    // cost the pool's cache amortizes away; the hot-switch row executes a
    // cached plan with per-sender batched delivery (sources resolved once
    // per (sender, tensor) — the switch.rs serialization fix this bench
    // guards). Moments ride along in both rows.
    let la = ShardLayout::build(&tiny, &EngineStrategy::uniform("dp2", 2, 1, 1, tiny.layers, 1))
        .unwrap();
    let lb = ShardLayout::build(&tiny, &EngineStrategy::uniform("tp2", 1, 2, 1, tiny.layers, 2))
        .unwrap();
    report(rep, "plan_switch dp2->tp2 (uncached, +moments)", "wall", it(100), || {
        std::hint::black_box(
            hetu::engine::plan_switch(&tiny, &la, &lb, true, &hetu::comm::UniformBandwidth, &[])
                .unwrap()
                .plan
                .num_messages(),
        );
    });
    let mut pool = hetu::temporal::StrategyPool::new(
        tiny,
        vec![
            (EngineStrategy::uniform("dp2", 2, 1, 1, tiny.layers, 1), 4096),
            (EngineStrategy::uniform("tp2", 1, 2, 1, tiny.layers, 2), 32768),
        ],
    )
    .unwrap();
    let mut eng3 = pool.spawn_engine(Runtime::native(tiny), 0, 42, 1e-3).unwrap();
    let mut corpus3 = SyntheticCorpus::new(11, tiny.vocab);
    eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
    report(rep, "engine hot-switch A<->B (cached, batched)", "wall", it(20), || {
        pool.switch_engine(&mut eng3, 1).unwrap();
        std::hint::black_box(pool.switch_engine(&mut eng3, 0).unwrap().wire_elems);
    });
    // cache hits are allocation-free: plan_for hands out the pooled
    // Arc<SwitchPlan> by refcount — no FusedBsrPlan/ShardLayout clones on
    // the steady-state switch path (the hot-switch constant-factor fix
    // this row guards; both keys are warm after the cycles above)
    report(rep, "pool plan_for cache hit (Arc handout)", "wall", it(5000), || {
        std::hint::black_box(
            pool.plan_for(0, 1, true, false, &hetu::comm::UniformBandwidth)
                .unwrap()
                .plan
                .num_messages(),
        );
    });

    // ragged engine step: the dispatcher's real packed windows (6 × [2,2]
    // micro-batches per DP pipeline) vs the fixed-shape row above — the
    // variable-shape executor path the temporal runtime drives
    let ragged_strat = EngineStrategy::uniform("dp2-ragged", 2, 1, 1, tiny.layers, 1);
    let mut eng4 = Engine::with_runtime(Runtime::native(tiny), ragged_strat, 42, 1e-3).unwrap();
    let windows: Vec<Vec<hetu::engine::WindowShape>> = (0..2)
        .map(|_| {
            (0..6).map(|_| hetu::engine::WindowShape { rows: vec![2, 2], seq_len: 2 }).collect()
        })
        .collect();
    eng4.set_microbatches(&windows).unwrap();
    let mut corpus4 = SyntheticCorpus::new(13, tiny.vocab);
    report(rep, "engine train_step dp2 ragged 12x[2,2]", "wall", it(10), || {
        std::hint::black_box(
            eng4.train_step(&mut |p, m| corpus4.window_for(&windows[p][m])).unwrap().loss,
        );
    });

    // ---- §7 progressive per-rank specialization. The lowering pass runs
    // once per (strategy, micro-batch counts) and on every switch — this
    // row is that re-specialization cost on the lowered C2 hetero
    // encoding (2 uneven pipelines, TP tail, both schedule groups).
    let c2e = hetu::strategy::lower(&c2, &tiny, &lopts).unwrap();
    let c2_layout = ShardLayout::build(&tiny, &c2e).unwrap();
    report(rep, "specialize lowered-C2 -> per-rank plans", "wall", it(500), || {
        std::hint::black_box(hetu::engine::specialize(&c2e, &c2_layout, false).unwrap().len());
    });

    // ---- §8 compiled MPMD artifacts. The same lowered-C2 hetero
    // encoding stepped by all three executors on identical pre-generated
    // micro-batches: the warm-up step asserts the bit-identity contract,
    // the three step rows expose the steady-state dispatch win (frozen
    // keys, no per-step readiness rebuild), and the compile row is the
    // one-off tape-freeze cost the pool's artifact cache amortizes away.
    let mbs: Vec<Vec<hetu::engine::MicroBatch>> = {
        let mut c = SyntheticCorpus::new(17, tiny.vocab);
        c2e.pipelines
            .iter()
            .map(|p| (0..p.num_microbatches).map(|_| c.microbatch(b_sz, s_sz)).collect())
            .collect()
    };
    let mut c2_ref = Engine::with_runtime(Runtime::native(tiny), c2e.clone(), 42, 1e-3).unwrap();
    let mut c2_ev = Engine::with_runtime(Runtime::native(tiny), c2e.clone(), 42, 1e-3).unwrap();
    let mut c2_cmp = Engine::with_runtime(Runtime::native(tiny), c2e.clone(), 42, 1e-3).unwrap();
    c2_cmp.set_exec_mode(ExecMode::Compiled);
    let w_ref = c2_ref.train_step_reference(&mut |p, m| mbs[p][m].clone()).unwrap();
    let w_ev = c2_ev.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    let w_cmp = c2_cmp.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    assert_eq!(
        w_ref.loss.to_bits(),
        w_ev.loss.to_bits(),
        "event-driven loss must be bit-identical to the reference interpreter"
    );
    assert_eq!(
        w_ref.loss.to_bits(),
        w_cmp.loss.to_bits(),
        "compiled loss must be bit-identical to the reference interpreter"
    );
    report(rep, "step wall lowered-C2 reference interpreter", "wall", it(10), || {
        std::hint::black_box(
            c2_ref.train_step_reference(&mut |p, m| mbs[p][m].clone()).unwrap().loss,
        );
    });
    report(rep, "step wall lowered-C2 event-driven executor", "wall", it(10), || {
        std::hint::black_box(c2_ev.train_step(&mut |p, m| mbs[p][m].clone()).unwrap().loss);
    });
    report(rep, "step wall lowered-C2 compiled dispatch", "wall", it(10), || {
        std::hint::black_box(c2_cmp.train_step(&mut |p, m| mbs[p][m].clone()).unwrap().loss);
    });
    let (c2_ev_best, c2_cmp_best) =
        (rep.rows[rep.rows.len() - 2].best_s, rep.rows[rep.rows.len() - 1].best_s);
    println!(
        "    compiled vs event-driven wall (best): {:.3}ms vs {:.3}ms ({:.2}x)",
        c2_cmp_best * 1e3,
        c2_ev_best * 1e3,
        c2_ev_best / c2_cmp_best.max(1e-12)
    );
    if !smoke {
        // the tentpole acceptance: dispatch-only replay beats per-step
        // dependency resolution on the steady-state step
        assert!(
            c2_cmp_best <= c2_ev_best,
            "compiled dispatch ({c2_cmp_best}s) must not lose to event-driven ({c2_ev_best}s)"
        );
    }
    report(rep, "compile lowered-C2 -> rank tape", "wall", it(100), || {
        c2_cmp.invalidate_compiled();
        std::hint::black_box(c2_cmp.compiled_program_cached().unwrap().num_segs());
    });

    // ---- §12 fused kernel execution. The compiled row above runs with
    // kernel fusion on (the default); this engine replays the same tape
    // with fusion off — every kernel allocates its own outputs and the
    // epilogue passes launch separately — isolating the kernel-level win
    // from the dispatch-level one. The launch-count rows record the
    // per-step kernel launches of each tape (a deterministic count, not
    // a timing), and the non-smoke asserts are the ISSUE 10 acceptance:
    // fused wall ≤ unfused wall, fused launches < unfused launches.
    let mut c2_unf = Engine::with_runtime(Runtime::native(tiny), c2e.clone(), 42, 1e-3).unwrap();
    c2_unf.set_exec_mode(ExecMode::Compiled);
    c2_unf.set_kernel_fusion(false);
    let w_unf = c2_unf.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    assert_eq!(
        w_ref.loss.to_bits(),
        w_unf.loss.to_bits(),
        "unfused compiled loss must be bit-identical to the reference interpreter"
    );
    report(rep, "step wall lowered-C2 compiled unfused", "wall", it(10), || {
        std::hint::black_box(c2_unf.train_step(&mut |p, m| mbs[p][m].clone()).unwrap().loss);
    });
    let c2_unf_best = rep.rows[rep.rows.len() - 1].best_s;
    let st_fused = c2_cmp.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    let st_unfused = c2_unf.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    let (lf, lu) = (st_fused.kernel_launches as f64, st_unfused.kernel_launches as f64);
    rep.row("kernel launches lowered-C2 fused step", "count", lf, lf);
    rep.row("kernel launches lowered-C2 unfused step", "count", lu, lu);
    println!(
        "    fused vs unfused compiled wall (best): {:.3}ms vs {:.3}ms ({:.2}x), \
         launches {} vs {}, fused kernel bytes alloc {}",
        c2_cmp_best * 1e3,
        c2_unf_best * 1e3,
        c2_unf_best / c2_cmp_best.max(1e-12),
        st_fused.kernel_launches,
        st_unfused.kernel_launches,
        st_fused.kernel_bytes_alloc
    );
    assert!(
        st_fused.kernel_launches > 0 && st_fused.kernel_launches < st_unfused.kernel_launches,
        "fused step launches {} must undercut the unfused tape's {}",
        st_fused.kernel_launches,
        st_unfused.kernel_launches
    );
    assert_eq!(
        st_fused.kernel_bytes_alloc, 0,
        "warm fused compiled step must allocate zero kernel floats"
    );
    if !smoke {
        // the ISSUE 10 acceptance: kernel fusion must not lose to the
        // unfused tape on the steady-state step
        assert!(
            c2_cmp_best <= c2_unf_best,
            "fused compiled step ({c2_cmp_best}s) must not lose to unfused ({c2_unf_best}s)"
        );
    }

    // ---- §10 observability. Tracing on: the compiled hot loop stores one
    // span per (op, participant) into the preallocated ring — this row is
    // the traced warm step, and the non-smoke assert bounds its cost
    // against the untraced compiled row above.
    c2_cmp.set_tracing(true);
    let w_tr = c2_cmp.train_step(&mut |p, m| mbs[p][m].clone()).unwrap();
    assert_eq!(
        w_ref.loss.to_bits(),
        w_tr.loss.to_bits(),
        "tracing must not perturb the numerics"
    );
    assert!(w_tr.breakdown.is_some(), "traced step must carry a span breakdown");
    report(rep, "trace_overhead", "wall", it(10), || {
        std::hint::black_box(c2_cmp.train_step(&mut |p, m| mbs[p][m].clone()).unwrap().loss);
    });
    let tr_best = rep.rows[rep.rows.len() - 1].best_s;
    println!(
        "    traced vs untraced compiled wall (best): {:.3}ms vs {:.3}ms ({:+.2}%)",
        tr_best * 1e3,
        c2_cmp_best * 1e3,
        (tr_best / c2_cmp_best.max(1e-12) - 1.0) * 1e2
    );
    if !smoke {
        assert!(
            tr_best <= c2_cmp_best * 1.05,
            "traced compiled step ({tr_best}s) must stay within 5% of untraced ({c2_cmp_best}s)"
        );
    }
    c2_cmp.set_tracing(false);

    // the interleaved post-switch step: a cached hot switch queues its
    // per-sender delivery batches, and the next step's executor rides
    // them on wire lanes concurrent with compute (§6.2 measured
    // interleave) — switch + first-step cost as one unit
    report(rep, "hot-switch + interleaved first step", "wall", it(10), || {
        pool.switch_engine(&mut eng3, 1).unwrap();
        let a = eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
        pool.switch_engine(&mut eng3, 0).unwrap();
        let b = eng3.train_step(&mut |_p, _m| corpus3.microbatch(b_sz, s_sz)).unwrap();
        assert!(
            a.exposed_switch_s <= a.switch_delivery_s && b.exposed_switch_s <= b.switch_delivery_s,
            "exposure is the non-overlapped remainder of the delivery"
        );
        std::hint::black_box(a.exposed_switch_s + b.exposed_switch_s);
    });

    // ---- PR9 cluster-scale rows: the interned key plumbing under
    // generated strategies two orders of magnitude past the testbed.
    // Specialize rows measure the per-rank planning pass against a
    // prebuilt layout (the layout builds once per strategy); compile rows
    // freeze the full rank tape. Both at 256 and 1024 engine devices.
    let gen256 = EngineStrategy::uniform("gen256", 8, 4, 8, tiny.layers, 8);
    let l256 = ShardLayout::build(&tiny, &gen256).unwrap();
    report(rep, "specialize 256-rank generated strategy", "wall", it(20), || {
        std::hint::black_box(hetu::engine::specialize(&gen256, &l256, false).unwrap().len());
    });
    let p256 = hetu::engine::specialize(&gen256, &l256, false).unwrap();
    let cnt256: Vec<usize> = gen256.pipelines.iter().map(|p| p.num_microbatches).collect();
    report(rep, "compile 256-rank generated strategy", "wall", it(10), || {
        std::hint::black_box(
            hetu::engine::compile_program(
                &p256,
                &gen256.pipelines,
                false,
                hetu::engine::ShapeClass::uniform(&cnt256, b_sz, s_sz),
                &tiny,
                true,
            )
            .unwrap()
            .num_segs(),
        );
    });
    let gen1024 = EngineStrategy::uniform("gen1024", 32, 4, 8, tiny.layers, 8);
    let l1024 = ShardLayout::build(&tiny, &gen1024).unwrap();
    report(rep, "specialize 1024-rank generated strategy", "wall", it(5), || {
        std::hint::black_box(hetu::engine::specialize(&gen1024, &l1024, false).unwrap().len());
    });
    let p1024 = hetu::engine::specialize(&gen1024, &l1024, false).unwrap();
    let cnt1024: Vec<usize> = gen1024.pipelines.iter().map(|p| p.num_microbatches).collect();
    report(rep, "compile 1024-rank generated strategy", "wall", it(5), || {
        std::hint::black_box(
            hetu::engine::compile_program(
                &p1024,
                &gen1024.pipelines,
                false,
                hetu::engine::ShapeClass::uniform(&cnt1024, b_sz, s_sz),
                &tiny,
                true,
            )
            .unwrap()
            .num_segs(),
        );
    });

    // full strategy search over a generated 128-node (1024-rank) mixed
    // cluster — hierarchical pruning keeps this sub-second
    let c1024 = hetu::cluster::ClusterSpec::new(11, 128).build();
    let sopts = hetu::strategy::SynthOptions::new(64, 4096);
    report(rep, "synth 1024-rank search", "wall", it(3), || {
        std::hint::black_box(
            hetu::strategy::synthesize(&c1024, &cm, &sopts).unwrap().ranked.len(),
        );
    });
    let synth_best = rep.rows[rep.rows.len() - 1].best_s;
    if !smoke {
        assert!(
            synth_best < 1.0,
            "1024-rank synthesis must stay sub-second (best {synth_best}s)"
        );
    }
    // and the winner actually lowers, specializes and compiles: the
    // synthesized strategy is runnable, not just rankable
    let srep = hetu::strategy::synthesize(&c1024, &cm, &sopts).unwrap();
    let winner = srep
        .ranked
        .iter()
        .find_map(|(s, _)| {
            let mut lo = lopts.clone();
            lo.total_microbatches = lo.total_microbatches.max(s.pipelines.len());
            hetu::strategy::lower(s, &tiny, &lo).ok()
        })
        .expect("a ranked 1024-rank strategy lowers");
    let wl = ShardLayout::build(&tiny, &winner).unwrap();
    let wp = hetu::engine::specialize(&winner, &wl, false).unwrap();
    let wcnt: Vec<usize> = winner.pipelines.iter().map(|p| p.num_microbatches).collect();
    let wc = hetu::engine::compile_program(
        &wp,
        &winner.pipelines,
        false,
        hetu::engine::ShapeClass::uniform(&wcnt, b_sz, s_sz),
        &tiny,
        true,
    )
    .unwrap();
    assert!(wc.num_segs() > 0, "synth winner compiles to a non-empty tape");
    println!(
        "    synth winner lowered: {} devices, {} segs",
        winner.num_devices(),
        wc.num_segs()
    );

    let path = rep.write().expect("write BENCH_hotpath.json");
    println!("wrote {}", path.display());
}
