//! Bench: regenerate Fig 14 (elastic training traces on homogeneous and
//! heterogeneous clusters, with per-configuration step times and
//! reconfiguration overheads).

fn main() {
    let t0 = std::time::Instant::now();
    for (name, table) in hetu::figures::fig14().expect("fig14") {
        let _ = name;
        println!("{}", table.markdown());
    }
    println!("(fig14 generated in {:.2}s)", t0.elapsed().as_secs_f64());
}
