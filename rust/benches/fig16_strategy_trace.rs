//! Bench: regenerate Fig 16 (per-step sequence-length variation and the
//! heterogeneous strategy Hetu-B selects each step).

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);
    let table = hetu::figures::fig16(steps).expect("fig16");
    println!("{}", table.markdown());
    // distribution check: the paper reports 97% of sequences under 8K
    let pct: Vec<f64> = table
        .rows
        .iter()
        .map(|r| r[4].trim_end_matches('%').parse::<f64>().unwrap())
        .collect();
    let mean = pct.iter().sum::<f64>() / pct.len() as f64;
    println!("mean %<8K across steps: {mean:.1}% (paper: 97%)");
}
