//! Bench: regenerate Fig 13 (per-step training time across model sizes and
//! cluster configurations, all four systems). `cargo bench --bench
//! fig13_hetero_clusters`.

fn main() {
    let t0 = std::time::Instant::now();
    let (table, rows) = hetu::figures::fig13().expect("fig13");
    println!("{}", table.markdown());
    // headline check: Hetu wins every heterogeneous scenario
    for r in &rows {
        if !r.label.contains('+') {
            continue;
        }
        let hetu = r.times.iter().find(|(s, _)| *s == "Hetu").unwrap().1;
        for (sys, t) in &r.times {
            if *sys != "Hetu" {
                let verdict = if hetu <= *t { "ok" } else { "VIOLATION" };
                println!("  {}: Hetu {hetu:.2}s vs {sys} {t:.2}s [{verdict}]", r.label);
            }
        }
    }
    println!("\n(fig13 generated in {:.2}s)", t0.elapsed().as_secs_f64());
}
