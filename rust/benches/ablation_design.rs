//! Bench: ablations of the design choices DESIGN.md calls out.
//!
//! 1. BSR planner ablation — heuristics × fusion over the C1→C2 transition
//!    (max per-sender volume, message count, estimated wall time);
//! 2. schedule ablation — GPipe vs 1F1B makespan across micro-batch counts;
//! 3. strategy-search ablation — generated heterogeneous layouts vs the
//!    paper's hand-tuned Table 5 entries vs uniform Megatron.

use hetu::cluster::Cluster;
use hetu::comm::BsrOptions;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::metrics::{fmt_s, Table};
use hetu::sim::simulate_step;
use hetu::spec::schedule::ScheduleKind;
use hetu::strategy::synth::{synthesize, SynthOptions};
use hetu::strategy::{tables, uniform};
use hetu::switch::plan_strategy_switch_avoiding;

fn main() {
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let c1 = tables::hetu_c1_32h20();
    let c2 = tables::hetu_c2_31h20();

    // ---- 1. BSR planner ablation
    let mut t1 = Table::new(
        "Ablation — BSR planner (C1→C2, rank 31 failed)",
        &["planner", "messages", "max sender MB", "est time"],
    );
    for (label, heuristics, fuse) in [
        ("no heuristics, unfused", false, false),
        ("no heuristics, fused", false, true),
        ("heuristics, unfused", true, false),
        ("heuristics, fused (Hetu)", true, true),
    ] {
        let rep = plan_strategy_switch_avoiding(
            &c1,
            &c2,
            &cm,
            &cluster,
            BsrOptions { heuristics },
            fuse,
            &[31],
        )
        .unwrap();
        let max_sender = rep
            .plan
            .sender_volumes(&cluster)
            .values()
            .map(|&(a, b)| a + b)
            .max()
            .unwrap_or(0);
        t1.row(vec![
            label.into(),
            rep.num_messages.to_string(),
            (max_sender / (1 << 20)).to_string(),
            fmt_s(rep.est_seconds),
        ]);
    }
    println!("{}", t1.markdown());

    // ---- 2. schedule ablation
    let mut t2 = Table::new(
        "Ablation — schedule × micro-batches (TP4 PP4, 16 H20)",
        &["microbatches", "GPipe", "1F1B", "GPipe peak act (rel)", "1F1B peak act (rel)"],
    );
    let ranks: Vec<u32> = (0..16).collect();
    for m in [4u64, 8, 16, 32, 64] {
        let mk = |k| uniform("x", &ranks, 1, 4, 4, 60, m, 1, 4096, k, true, false).unwrap();
        let cl16 = Cluster::h20(16);
        let tg = simulate_step(&cl16, &cm, &mk(ScheduleKind::GPipe)).unwrap().step_s;
        let t1f = simulate_step(&cl16, &cm, &mk(ScheduleKind::OneFOneB)).unwrap().step_s;
        let rg =
            hetu::strategy::memory::resident_microbatches(ScheduleKind::GPipe, 4, 0, m as u32);
        let r1 =
            hetu::strategy::memory::resident_microbatches(ScheduleKind::OneFOneB, 4, 0, m as u32);
        t2.row(vec![
            m.to_string(),
            fmt_s(tg),
            fmt_s(t1f),
            format!("{rg}x"),
            format!("{r1}x"),
        ]);
    }
    println!("{}", t2.markdown());

    // ---- 3. strategy-search ablation
    let mut t3 = Table::new(
        "Ablation — layout source (32B, 16xH800+16xH20)",
        &["layout", "step time"],
    );
    let hetero = Cluster::h800_16_h20_16();
    let t_table5 =
        simulate_step(&hetero, &cm, &tables::hetu_32b_16h800_16h20()).unwrap().step_s;
    let (gen_best, t_gen) = synthesize(&hetero, &cm, &SynthOptions::legacy(64, 4096))
        .unwrap()
        .best()
        .expect("feasible candidate")
        .clone();
    let mcfg = hetu::baselines::megatron::table4("llama-32b", 16, 16).unwrap();
    let t_uniform = hetu::baselines::megatron::step_time(&hetero, &cm, mcfg, 64, 4096).unwrap();
    t3.row(vec!["paper Table 5 (hand-tuned)".into(), fmt_s(t_table5)]);
    t3.row(vec![format!("cost-model search ({})", gen_best.name), fmt_s(t_gen)]);
    t3.row(vec!["uniform Megatron".into(), fmt_s(t_uniform)]);
    println!("{}", t3.markdown());
    println!(
        "note: the search optimizes *our* cost model, so it can edge out the\n\
         paper's layout (which is optimal on the authors' real profiles);\n\
         both sit far below uniform Megatron — the claim under test.\n\
         BSR note: with rank 31 dead, the largest single flow has exactly one\n\
         surviving owner, so every planner shares the same bottleneck sender;\n\
         heuristics still shift aggregate traffic onto NVLink (see Table 2)."
    );
}
