//! Bench: regenerate Table 2 (C1→C2 per-sender communication volumes over
//! NVLink vs InfiniBand, unfused-no-heuristics vs fused BSR planning).

fn main() {
    let table = hetu::figures::table2().expect("table2");
    println!("{}", table.markdown());

    // Invariant the paper highlights: both planners move the same total
    // volume; the fused planner spreads it and prefers NVLink.
    let sum = |planner: &str, col: usize| -> u64 {
        table
            .rows
            .iter()
            .filter(|r| r[0] == planner)
            .map(|r| r[col].parse::<u64>().unwrap_or(0))
            .sum()
    };
    let (u_nv, u_ib) = (sum("unfused w/o heuristics", 2), sum("unfused w/o heuristics", 3));
    let (f_nv, f_ib) = (sum("fused", 2), sum("fused", 3));
    println!("unfused totals: NVLink {u_nv} MB + IB {u_ib} MB = {} MB", u_nv + u_ib);
    println!("fused   totals: NVLink {f_nv} MB + IB {f_ib} MB = {} MB", f_nv + f_ib);
    println!(
        "fused NVLink share {:.0}% vs unfused {:.0}% [{}]",
        100.0 * f_nv as f64 / (f_nv + f_ib).max(1) as f64,
        100.0 * u_nv as f64 / (u_nv + u_ib).max(1) as f64,
        if f_nv >= u_nv { "ok" } else { "VIOLATION" }
    );
}
