//! Bench: regenerate Fig 18 — left (per-rank time breakdown under C1/C2)
//! and right (C1→C2 transition overhead with the three BSR planners).

fn main() {
    let left = hetu::figures::fig18_left().expect("fig18 left");
    println!("{}", left.markdown());
    let right = hetu::figures::fig18_right().expect("fig18 right");
    println!("{}", right.markdown());
}
