//! Bench: regenerate Fig 18.
//!
//! The left panel is rebuilt on the §10 span recorder: per-rank
//! compute/comm/bubble seconds are *measured* by tracing one engine step
//! on the lowered-C2 hetero encoding (event-driven executor, so the clock
//! is the modeled replay — labelled as such), cross-checked so the mean
//! components sum to the step makespan within 5%. The analytic simulator
//! table ([`hetu::figures::fig18_left`]) prints alongside as the modeled
//! reference, and is the fallback when tracing yields no spans. The right
//! panel (C1→C2 transition overhead) is unchanged.

use hetu::coordinator::SyntheticCorpus;
use hetu::engine::Engine;
use hetu::obs::per_rank;
use hetu::runtime::{native, Runtime};
use hetu::strategy::{tables, LowerOptions};

fn main() {
    let tiny = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] };
    let c2e = hetu::strategy::lower(&tables::hetu_c2_31h20(), &tiny, &lopts).expect("lower C2");
    let mut eng = Engine::with_runtime(Runtime::native(tiny), c2e, 42, 1e-3).expect("engine");
    eng.set_tracing(true);
    let mut corpus = SyntheticCorpus::new(17, tiny.vocab);
    let stats = eng
        .train_step(&mut |_p, _m| corpus.microbatch(tiny.batch, tiny.seq))
        .expect("traced step");
    let spans = eng.last_step_spans().to_vec();
    if spans.is_empty() {
        println!("(tracing yielded no spans — modeled table only)\n");
    } else {
        let b = stats.breakdown.expect("traced step carries a breakdown");
        let sum = b.components_sum_s();
        assert!(
            (sum - stats.makespan_s).abs() <= 0.05 * stats.makespan_s.max(1e-12),
            "breakdown components ({sum}s) must sum to the makespan ({}s) within 5%",
            stats.makespan_s
        );
        println!("Fig 18 (left, measured) — span breakdown by rank, lowered C2 [modeled clock]");
        println!(
            "| {:>4} | {:>11} | {:>11} | {:>11} | {:>11} | {:>11} |",
            "rank", "compute", "comm", "optim", "bubble", "step"
        );
        for r in per_rank(&spans, stats.makespan_s) {
            println!(
                "| {:>4} | {:>9.3}ms | {:>9.3}ms | {:>9.3}ms | {:>9.3}ms | {:>9.3}ms |",
                r.rank,
                r.compute_s * 1e3,
                r.comm_s * 1e3,
                r.optim_s * 1e3,
                r.bubble_s * 1e3,
                stats.makespan_s * 1e3
            );
        }
        println!(
            "step mean: compute {:.3}ms + comm {:.3}ms + optim {:.3}ms + bubble {:.3}ms = \
             {:.3}ms (makespan {:.3}ms, critical path {:.3}ms)\n",
            b.compute_s * 1e3,
            b.comm_s * 1e3,
            b.optim_s * 1e3,
            b.bubble_s * 1e3,
            sum * 1e3,
            stats.makespan_s * 1e3,
            b.critical_path_s * 1e3
        );
    }
    let left = hetu::figures::fig18_left().expect("fig18 left");
    println!("{}", left.markdown());
    let right = hetu::figures::fig18_right().expect("fig18 right");
    println!("{}", right.markdown());
}
