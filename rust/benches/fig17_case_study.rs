//! Bench: regenerate Fig 17 (the C2 deployment's resolved communication
//! pattern: AG/RS within stages, SR/BSR between, AR/SplitAR for gradient
//! synchronization).

fn main() {
    let table = hetu::figures::fig17().expect("fig17");
    println!("{}", table.markdown());
}
