"""L1 kernel correctness: Pallas vs pure-jnp oracles.

`hypothesis` is unavailable in this image, so the shape/dtype sweeps are
explicit parameterized grids — same methodology (many distinct cases, each
asserting allclose against the oracle).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.flash_attention import flash_attention, vmem_bytes
from compile.kernels.ref import attention_ref, rmsnorm_ref, softmax_xent_ref
from compile.kernels.rmsnorm import rmsnorm


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ------------------------------------------------------------------ rmsnorm

RMS_SHAPES = [
    (4, 8),
    (2, 16, 32),
    (1, 128, 768),
    (3, 5, 64),  # rows not a multiple of block_rows → padding path
    (2, 200, 96),
]


@pytest.mark.parametrize("shape", RMS_SHAPES)
def test_rmsnorm_matches_ref(shape):
    key = jax.random.PRNGKey(hash(shape) % (2**31))
    k1, k2 = jax.random.split(key)
    x = rand(k1, shape)
    g = rand(k2, shape[-1:]) + 1.0
    got = rmsnorm(x, g)
    want = rmsnorm_ref(x, g)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_rows", [8, 32, 128])
def test_rmsnorm_block_size_invariance(block_rows):
    key = jax.random.PRNGKey(7)
    x = rand(key, (4, 96, 64))
    g = jnp.ones((64,))
    got = rmsnorm(x, g, block_rows=block_rows)
    np.testing.assert_allclose(got, rmsnorm_ref(x, g), rtol=1e-5, atol=1e-5)


def test_rmsnorm_gain_scales_output():
    x = rand(jax.random.PRNGKey(0), (2, 8, 16))
    g = jnp.ones((16,))
    a = rmsnorm(x, g)
    b = rmsnorm(x, 2.0 * g)
    np.testing.assert_allclose(2.0 * a, b, rtol=1e-6)


def test_rmsnorm_rms_is_unit():
    x = rand(jax.random.PRNGKey(1), (3, 4, 256), scale=5.0)
    out = rmsnorm(x, jnp.ones((256,)))
    rms = jnp.sqrt(jnp.mean(out**2, axis=-1))
    np.testing.assert_allclose(rms, jnp.ones_like(rms), rtol=1e-3)


# ----------------------------------------------------------- flash attention

ATTN_CASES = [
    # (B, S, NH, HD, block_q, block_k)
    (1, 64, 2, 16, 32, 32),
    (2, 128, 4, 32, 64, 64),
    (1, 128, 12, 64, 128, 128),
    (2, 128, 3, 64, 128, 64),
    (1, 256, 2, 32, 64, 128),
    (3, 64, 1, 8, 64, 16),
]


@pytest.mark.parametrize("b,s,nh,hd,bq,bk", ATTN_CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(b, s, nh, hd, bq, bk, causal):
    key = jax.random.PRNGKey((b * 1000 + s + nh * 7 + hd) % (2**31))
    kq, kk, kv = jax.random.split(key, 3)
    q = rand(kq, (b, s, nh, hd))
    k = rand(kk, (b, s, nh, hd))
    v = rand(kv, (b, s, nh, hd))
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_first_token_is_value():
    # causal: position 0 attends only to itself → output = v[0]
    q = rand(jax.random.PRNGKey(3), (1, 64, 2, 16))
    k = rand(jax.random.PRNGKey(4), (1, 64, 2, 16))
    v = rand(jax.random.PRNGKey(5), (1, 64, 2, 16))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)


def test_flash_attention_softmax_rows_sum_to_one():
    # uniform q,k → each row's output is the (masked) mean of v
    s = 64
    q = jnp.zeros((1, s, 1, 8))
    k = jnp.zeros((1, s, 1, 8))
    v = rand(jax.random.PRNGKey(6), (1, s, 1, 8))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = jnp.cumsum(v[0, :, 0, :], axis=0) / jnp.arange(1, s + 1)[:, None]
    np.testing.assert_allclose(out[0, :, 0, :], want, rtol=1e-4, atol=1e-5)


def test_flash_attention_rejects_ragged_blocks():
    q = jnp.zeros((1, 100, 1, 8))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, block_q=64, block_k=64)


def test_vmem_estimate_is_sane():
    # S=2048, HD=128 fp32: K/V panels dominate; must stay under 16 MiB VMEM
    assert vmem_bytes(2048, 128) < 16 * 1024 * 1024


# ------------------------------------------------------------------ softmax

def test_xent_matches_manual():
    logits = rand(jax.random.PRNGKey(9), (32, 50))
    targets = jax.random.randint(jax.random.PRNGKey(10), (32,), 0, 50)
    got = softmax_xent_ref(logits, targets)
    p = jax.nn.log_softmax(logits)
    want = -jnp.mean(jnp.take_along_axis(p, targets[:, None], axis=-1))
    np.testing.assert_allclose(got, want, rtol=1e-6)
