"""L2 model correctness: TP partial-sum exactness, backward-pass gradients,
and the distributed-execution contract the Rust engine relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelCfg(layers=2, hidden=64, ffn=128, heads=4, vocab=97)
B, S = 2, 32


def init_block(key, cfg, tp=1):
    shapes = M.block_param_shapes(cfg, tp)
    keys = jax.random.split(key, len(shapes))
    return [
        (jax.random.normal(k, s) * 0.05).astype(jnp.float32)
        for k, (_, s) in zip(keys, shapes)
    ]


def shard_block(params, cfg, tp):
    """Split full (tp=1) block params into `tp` Megatron shards."""
    g1, wq, wk, wv, wo, g2, w1, w2 = params
    shards = []
    h, f = cfg.hidden, cfg.ffn
    for i in range(tp):
        cs = slice(i * h // tp, (i + 1) * h // tp)  # attn columns/rows
        fs = slice(i * f // tp, (i + 1) * f // tp)  # ffn columns/rows
        shards.append(
            [g1, wq[:, cs], wk[:, cs], wv[:, cs], wo[cs, :], g2, w1[:, fs], w2[fs, :]]
        )
    return shards


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_partial_sums_equal_full_block(tp):
    key = jax.random.PRNGKey(0)
    params = init_block(key, CFG, 1)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, CFG.hidden)) * 0.5
    full = M.block_fwd(CFG, 1, False, *params, x)
    shards = shard_block(params, CFG, tp)
    partial_sum = sum(M.block_fwd(CFG, tp, False, *sh, x) for sh in shards)
    np.testing.assert_allclose(partial_sum, full, rtol=2e-4, atol=2e-5)


def test_pallas_and_ref_block_agree():
    key = jax.random.PRNGKey(2)
    params = init_block(key, CFG, 1)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, CFG.hidden)) * 0.5
    a = M.block_fwd(CFG, 1, True, *params, x)
    b = M.block_fwd(CFG, 1, False, *params, x)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tp", [1, 2])
def test_block_bwd_matches_autodiff(tp):
    key = jax.random.PRNGKey(4)
    full = init_block(key, CFG, 1)
    x = jax.random.normal(jax.random.PRNGKey(5), (B, S, CFG.hidden)) * 0.5
    dy = jax.random.normal(jax.random.PRNGKey(6), (B, S, CFG.hidden)) * 0.1

    # distributed backward: dx = dy + sum_i dx_partial_i (engine contract,
    # for the residual block y = x + sum_i f_i(x))
    shards = shard_block(full, CFG, tp)
    outs = [M.block_bwd(CFG, tp, *sh, x, dy) for sh in shards]
    dx = dy + sum(o[0] for o in outs)

    # oracle: vjp of the full residual block
    def residual_block(params, xx):
        return xx + M.block_fwd(CFG, 1, False, *params, xx)

    _, vjp = jax.vjp(residual_block, tuple(full), x)
    dparams_want, dx_want = vjp(dy)
    np.testing.assert_allclose(dx, dx_want, rtol=2e-4, atol=2e-5)

    # parameter grads: shard grads must tile the full grads
    if tp == 1:
        for got, want in zip(outs[0][1:], dparams_want):
            np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    else:
        h = CFG.hidden
        wq_full = jnp.concatenate([o[2] for o in outs], axis=1)
        np.testing.assert_allclose(wq_full, dparams_want[1], rtol=2e-4, atol=2e-5)
        wo_full = jnp.concatenate([o[5] for o in outs], axis=0)
        np.testing.assert_allclose(wo_full, dparams_want[4], rtol=2e-4, atol=2e-5)
        # replicated gains: each shard holds the full dg (summing across
        # shards would double-count; engine divides by tp after AR)
        g1_sum = sum(o[1] for o in outs)
        np.testing.assert_allclose(g1_sum, dparams_want[0], rtol=2e-4, atol=2e-5)
        assert h == CFG.hidden


def test_head_fwd_grad_seed_matches_autodiff():
    key = jax.random.PRNGKey(7)
    gf = jnp.ones((CFG.hidden,))
    wout = jax.random.normal(key, (CFG.hidden, CFG.vocab)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(8), (B, S, CFG.hidden)) * 0.5
    t = jax.random.randint(jax.random.PRNGKey(9), (B, S), 0, CFG.vocab)
    loss, dx = M.head_fwd(CFG, gf, wout, x, t)

    def f(xx):
        from compile.kernels.ref import rmsnorm_ref, softmax_xent_ref

        xn = rmsnorm_ref(xx, gf)
        return softmax_xent_ref((xn @ wout).reshape(-1, CFG.vocab), t.reshape(-1))

    want_loss, want_dx = jax.value_and_grad(f)(x)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-5)
    np.testing.assert_allclose(dx, want_dx, rtol=1e-4, atol=1e-6)


def test_embed_roundtrip_gradients():
    emb = jax.random.normal(jax.random.PRNGKey(10), (CFG.vocab, CFG.hidden))
    tok = jax.random.randint(jax.random.PRNGKey(11), (B, S), 0, CFG.vocab)
    x = M.embed_fwd(emb, tok)
    assert x.shape == (B, S, CFG.hidden)
    dx = jnp.ones_like(x)
    demb = M.embed_bwd(tok, dx, CFG.vocab)

    def f(e):
        return jnp.sum(M.embed_fwd(e, tok))

    want = jax.grad(f)(emb)
    np.testing.assert_allclose(demb, want, rtol=1e-6)


def test_reference_loss_is_finite_and_decreasable():
    key = jax.random.PRNGKey(12)
    layers = [init_block(k, CFG, 1) for k in jax.random.split(key, CFG.layers)]
    emb = jax.random.normal(jax.random.PRNGKey(13), (CFG.vocab, CFG.hidden)) * 0.05
    gf = jnp.ones((CFG.hidden,))
    wout = jax.random.normal(jax.random.PRNGKey(14), (CFG.hidden, CFG.vocab)) * 0.05
    tok = jax.random.randint(jax.random.PRNGKey(15), (B, S), 0, CFG.vocab)
    tgt = jax.random.randint(jax.random.PRNGKey(16), (B, S), 0, CFG.vocab)
    loss = M.reference_loss(CFG, layers, emb, gf, wout, tok, tgt)
    assert jnp.isfinite(loss)
    # near-uniform logits → loss ≈ log(V)
    assert abs(float(loss) - float(jnp.log(CFG.vocab))) < 1.0


def test_param_shapes_cover_tp_degrees():
    for tp in (1, 2, 4):
        shapes = M.block_param_shapes(M.TINY, tp)
        assert len(shapes) == 8
        total = sum(int(np.prod(s)) for _, s in shapes)
        # shards of the sharded tensors tile the full parameter count
        full = M.TINY.params_per_layer() + 2 * M.TINY.hidden
        assert total * tp >= full


def test_head_step_fuses_all_gradients():
    key = jax.random.PRNGKey(20)
    gf = jnp.ones((CFG.hidden,))
    wout = jax.random.normal(key, (CFG.hidden, CFG.vocab)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(21), (B, S, CFG.hidden)) * 0.5
    t = jax.random.randint(jax.random.PRNGKey(22), (B, S), 0, CFG.vocab)
    loss, dx, dgf, dwout = M.head_step(CFG, gf, wout, x, t)
    want_loss, want_dx = M.head_fwd(CFG, gf, wout, x, t)
    want_dgf, want_dwout = M.head_grads(CFG, gf, wout, x, t)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-6)
    np.testing.assert_allclose(dx, want_dx, rtol=1e-5, atol=1e-7)
    np.testing.assert_allclose(dgf, want_dgf, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(dwout, want_dwout, rtol=1e-5, atol=1e-7)
