"""AOT exporter: lower the L2 model to HLO **text** artifacts for the Rust
runtime.

Interchange is HLO text, not a serialized ``HloModuleProto``: jax >= 0.5
emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out ../artifacts [--batch 2] [--seq 128]

Emits one ``<name>.hlo.txt`` per exported function plus ``manifest.json``
describing input shapes/dtypes and output arity (the Rust runtime validates
against it).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

TP_DEGREES = (1, 2, 4)


def to_hlo_text(fn, specs):
    """Lower a function at the given ShapeDtypeStructs to HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_all(out_dir: str, batch: int, seq: int, cfg: M.ModelCfg):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "config": {
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "ffn": cfg.ffn,
            "heads": cfg.heads,
            "vocab": cfg.vocab,
            "batch": batch,
            "seq": seq,
            "tp_degrees": list(TP_DEGREES),
        },
        "artifacts": {},
    }

    def emit(name, fn, specs, n_outputs):
        text = to_hlo_text(fn, specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [
                [list(s.shape), "i32" if s.dtype == jnp.int32 else "f32"] for s in specs
            ],
            "outputs": n_outputs,
        }
        print(f"  {name}: {len(text) / 1024:.0f} KiB, {len(specs)} inputs")

    h = cfg.hidden
    x_spec = spec((batch, seq, h))
    tok_spec = spec((batch, seq), jnp.int32)

    # embedding
    emit(
        "embed_fwd",
        lambda emb, tok: (M.embed_fwd(emb, tok),),
        [spec((cfg.vocab, h)), tok_spec],
        1,
    )
    emit(
        "embed_bwd",
        lambda tok, dx: (M.embed_bwd(tok, dx, cfg.vocab),),
        [tok_spec, x_spec],
        1,
    )

    # blocks per TP degree
    for tp in TP_DEGREES:
        pshapes = [spec(s) for _, s in M.block_param_shapes(cfg, tp)]
        emit(
            f"block_fwd_tp{tp}",
            functools.partial(
                lambda tp_, *a: (M.block_fwd(cfg, tp_, True, *a),), tp
            ),
            pshapes + [x_spec],
            1,
        )
        emit(
            f"block_bwd_tp{tp}",
            functools.partial(lambda tp_, *a: M.block_bwd(cfg, tp_, *a), tp),
            pshapes + [x_spec, x_spec],
            9,
        )

    # head: loss + every gradient in one fused backward (§Perf, L2)
    emit(
        "head_step",
        lambda gf, wout, x, t: M.head_step(cfg, gf, wout, x, t),
        [spec((h,)), spec((h, cfg.vocab)), x_spec, tok_spec],
        4,
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {out_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    # Compiled micro-batch shape. Small by default: the validation image is
    # a single CPU core, and the ~100M-param model costs ~0.6 GFLOP/token.
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    export_all(args.out, args.batch, args.seq, M.TINY)


if __name__ == "__main__":
    main()
