"""L2: the JAX model — a parallel-block (GPT-J/PaLM-style) Llama variant.

The residual form ``y = x + Attn(LN(x)) + MLP(LN(x))`` is chosen so that
Megatron-style tensor parallelism needs exactly **one all-reduce per block
per direction**, and that all-reduce is *owned by the Rust engine* (L3):
the per-shard forward returns a partial sum, Rust all-reduces across the TP
group and adds the residual. The backward artifact recomputes the block
forward from the saved input (per-block activation checkpointing) and
returns `(dx_partial, dparams_shard)`; Rust all-reduces `dx_partial` and
adds `dy`.

Exported functions (AOT-lowered by ``aot.py``):

* ``embed_fwd(emb, tokens) -> x``
* ``block_fwd_tp{d}(g1, wq, wk, wv, wo, g2, w1, w2, x) -> y_partial``
* ``block_bwd_tp{d}(params..., x, dy) -> (dx_partial, dparams...)``
* ``head_fwd(gf, wout, x, targets) -> (loss, dx_seed)`` where ``dx_seed``
  is ``dL/dx`` (head backward fused into the forward for one fewer
  artifact round-trip)
* ``head_grads(gf, wout, x, targets) -> (dgf, dwout)``
* ``embed_bwd(tokens, dx) -> demb``

The forward uses the L1 Pallas kernels (flash attention, fused RMSNorm);
backward passes differentiate the jnp oracles (same math — Pallas interpret
kernels carry no VJP), which keeps gradients exact w.r.t. the reference.
"""

import jax
import jax.numpy as jnp

from compile.kernels.flash_attention import flash_attention
from compile.kernels.ref import attention_ref, rmsnorm_ref, softmax_xent_ref
from compile.kernels.rmsnorm import rmsnorm


class ModelCfg:
    """Tiny-100M architecture (matches rust `ModelCfg::tiny_100m`)."""

    def __init__(self, layers=8, hidden=768, ffn=3072, heads=12, vocab=32000):
        self.layers = layers
        self.hidden = hidden
        self.ffn = ffn
        self.heads = heads
        self.vocab = vocab
        assert hidden % heads == 0
        self.head_dim = hidden // heads

    def params_per_layer(self):
        return 4 * self.hidden * self.hidden + 2 * self.hidden * self.ffn


TINY = ModelCfg()


def block_param_shapes(cfg: ModelCfg, tp: int):
    """Shard shapes of one block's parameters at TP degree ``tp``."""
    h, f = cfg.hidden, cfg.ffn
    assert cfg.heads % tp == 0 and f % tp == 0
    return [
        ("g1", (h,)),
        ("wq", (h, h // tp)),
        ("wk", (h, h // tp)),
        ("wv", (h, h // tp)),
        ("wo", (h // tp, h)),
        ("g2", (h,)),
        ("w1", (h, f // tp)),
        ("w2", (f // tp, h)),
    ]


def block_fwd(cfg: ModelCfg, tp: int, use_pallas: bool, g1, wq, wk, wv, wo, g2, w1, w2, x):
    """One parallel block's *partial* output for a TP shard.

    ``sum over shards + x`` equals the full block output. The attention
    heads and FFN columns are Megatron-sharded; RMSNorm gains are
    replicated.
    """
    b, s, h = x.shape
    nh = cfg.heads // tp
    norm = rmsnorm if use_pallas else rmsnorm_ref
    attn_fn = flash_attention if use_pallas else attention_ref

    xn = norm(x, g1)
    q = (xn @ wq).reshape(b, s, nh, cfg.head_dim)
    k = (xn @ wk).reshape(b, s, nh, cfg.head_dim)
    v = (xn @ wv).reshape(b, s, nh, cfg.head_dim)
    att = attn_fn(q, k, v, causal=True).reshape(b, s, h // tp)
    att_out = att @ wo  # partial over TP

    xn2 = norm(x, g2)
    hh = jax.nn.gelu(xn2 @ w1)
    mlp_out = hh @ w2  # partial over TP

    return att_out + mlp_out


def block_bwd(cfg: ModelCfg, tp: int, g1, wq, wk, wv, wo, g2, w1, w2, x, dy):
    """VJP of the (reference-math) partial block forward.

    Returns ``(dx_partial, dg1, dwq, dwk, dwv, dwo, dg2, dw1, dw2)``.
    The engine computes ``dx = dy + AllReduce(dx_partial)``.
    """

    def f(params, xx):
        return block_fwd(cfg, tp, False, *params, xx)

    params = (g1, wq, wk, wv, wo, g2, w1, w2)
    _, vjp = jax.vjp(f, params, x)
    dparams, dx = vjp(dy)
    return (dx,) + tuple(dparams)


def embed_fwd(emb, tokens):
    """Token embedding lookup: ``[V,H],[B,S] -> [B,S,H]``."""
    return jnp.take(emb, tokens, axis=0)


def embed_bwd(tokens, dx, vocab: int):
    """Embedding gradient (scatter-add)."""
    b, s, h = dx.shape
    flat_tok = tokens.reshape(-1)
    flat_dx = dx.reshape(-1, h)
    return jnp.zeros((vocab, h), flat_dx.dtype).at[flat_tok].add(flat_dx)


def head_fwd(cfg: ModelCfg, gf, wout, x, targets):
    """Final RMSNorm + LM head + mean softmax-xent, fused with its own
    input-gradient (the backward seed Rust feeds into the last block)."""

    def loss_fn(xx):
        xn = rmsnorm_ref(xx, gf)
        logits = (xn @ wout).reshape(-1, cfg.vocab)
        return softmax_xent_ref(logits, targets.reshape(-1))

    loss, dx = jax.value_and_grad(loss_fn)(x)
    return loss, dx


def head_grads(cfg: ModelCfg, gf, wout, x, targets):
    """Parameter gradients of the head (gain + output matrix)."""

    def loss_fn(gg, ww):
        xn = rmsnorm_ref(x, gg)
        logits = (xn @ ww).reshape(-1, cfg.vocab)
        return softmax_xent_ref(logits, targets.reshape(-1))

    dgf, dwout = jax.grad(loss_fn, argnums=(0, 1))(gf, wout)
    return dgf, dwout


def head_step(cfg: ModelCfg, gf, wout, x, targets):
    """Fused head: loss + ALL gradients (dx, dgf, dwout) in one backward
    pass — one PJRT round-trip and one shared forward instead of the
    separate `head_fwd`/`head_grads` pair (§Perf, L2)."""

    def loss_fn(xx, gg, ww):
        xn = rmsnorm_ref(xx, gg)
        logits = (xn @ ww).reshape(-1, cfg.vocab)
        return softmax_xent_ref(logits, targets.reshape(-1))

    loss, (dx, dgf, dwout) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(x, gf, wout)
    return loss, dx, dgf, dwout


def reference_loss(cfg: ModelCfg, params, emb, gf, wout, tokens, targets):
    """Whole-model single-device loss (oracle for the engine's distributed
    execution): ``params`` is a list of per-layer tuples at TP=1."""
    x = embed_fwd(emb, tokens)
    for layer in params:
        x = x + block_fwd(cfg, 1, False, *layer, x)
    loss, _ = head_fwd(cfg, gf, wout, x, targets)
    return loss
