"""L1 Pallas kernel: fused RMSNorm.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid row per (batch,
sequence-tile); the ``[block_rows, H]`` tile is resident in VMEM, the
mean-of-squares reduction and the scale are fused in a single pass on the
VPU — no extra HBM round-trip for the variance. ``interpret=True`` is
mandatory on the CPU PJRT backend (Mosaic custom-calls cannot run there).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = x * jax.lax.rsqrt(ms + eps) * g_ref[...]


def rmsnorm(x, g, eps: float = 1e-6, block_rows: int = 128, interpret: bool = True):
    """Fused RMSNorm via Pallas.

    Args:
      x: ``[..., H]`` activations (flattened to rows internally).
      g: ``[H]`` gain.
      eps: numerical floor.
      block_rows: rows per VMEM tile (sublane-aligned; 128 suits the 8x128
        vector registers and keeps the tile ≤ 128·H·4 bytes of VMEM).
      interpret: run in interpret mode (required on CPU).

    Returns:
      Same shape/dtype as ``x``.
    """
    orig_shape = x.shape
    h = x.shape[-1]
    rows = 1
    for d in x.shape[:-1]:
        rows *= d
    x2 = x.reshape(rows, h)

    rows_padded = ((rows + block_rows - 1) // block_rows) * block_rows
    if rows_padded != rows:
        x2 = jnp.pad(x2, ((0, rows_padded - rows), (0, 0)))

    grid = (rows_padded // block_rows,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((rows_padded, h), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        interpret=interpret,
    )(x2, g)
    return out[:rows].reshape(orig_shape)
