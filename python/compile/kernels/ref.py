"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: every Pallas kernel is compared
against its oracle in ``python/tests``, and the backward-pass artifacts are
built by differentiating *these* (Pallas interpret-mode kernels are not
generally differentiable without a custom VJP; the math is identical).
"""

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, g, eps: float = 1e-6):
    """RMSNorm over the last dim: ``x / rms(x) * g``.

    Args:
      x: ``[..., H]`` activations.
      g: ``[H]`` gain.
      eps: numerical floor.
    """
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def attention_ref(q, k, v, causal: bool = True):
    """Multi-head scaled-dot-product attention.

    Args:
      q, k, v: ``[B, S, NH, HD]``.
      causal: apply a causal mask.

    Returns:
      ``[B, S, NH, HD]`` attention output.
    """
    b, s, nh, hd = q.shape
    scale = 1.0 / jnp.sqrt(hd).astype(q.dtype)
    # [B, NH, S, S]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def softmax_xent_ref(logits, targets):
    """Mean softmax cross-entropy.

    Args:
      logits: ``[N, V]``.
      targets: ``[N]`` int32 class ids.
    """
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
