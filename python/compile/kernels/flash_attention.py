"""L1 Pallas kernel: causal flash attention (online softmax).

TPU mapping (DESIGN.md §Hardware-Adaptation): FlashAttention's GPU
threadblock schedule is re-expressed as a Pallas grid over
``(batch, head, q-tile)``. Each grid point holds one ``[block_q, HD]`` query
tile plus the head's K/V panels in VMEM and streams over K-tiles with the
running-max/denominator recurrence, accumulating in fp32 — the MXU sees
``[block_q, HD] x [HD, block_k]`` contractions (128-aligned when
``block_q = block_k = 128``, HD = 64/128). The softmax never materializes
the ``S x S`` score matrix in HBM.

``interpret=True`` is mandatory on CPU PJRT (Mosaic custom-calls cannot run
there); real-TPU perf is estimated from the VMEM/MXU structure in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q, block_k, causal):
    qi = pl.program_id(2)
    q = q_ref[0, :, 0, :]  # [block_q, HD]
    k = k_ref[0, :, 0, :]  # [S, HD]
    v = v_ref[0, :, 0, :]  # [S, HD]
    seq = k.shape[0]
    hd = q.shape[-1]
    scale = (1.0 / (hd ** 0.5)).astype(q.dtype) if hasattr(hd, "astype") else 1.0 / (hd ** 0.5)
    qs = (q * scale).astype(jnp.float32)
    q_idx = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kb = seq // block_k

    def body(j, carry):
        acc, m, l = carry
        kk = jax.lax.dynamic_slice(k, (j * block_k, 0), (block_k, hd)).astype(jnp.float32)
        vv = jax.lax.dynamic_slice(v, (j * block_k, 0), (block_k, hd)).astype(jnp.float32)
        s = qs @ kk.T  # [block_q, block_k] on the MXU
        if causal:
            k_idx = j * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_idx[:, None] >= k_idx[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ vv
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
):
    """Causal flash attention.

    Args:
      q, k, v: ``[B, S, NH, HD]``.
      causal: apply the causal mask.
      block_q / block_k: VMEM tile sizes (128 aligns with the MXU).
      interpret: interpret mode (required on CPU PJRT).

    Returns:
      ``[B, S, NH, HD]``, same dtype as ``q``.
    """
    b, s, nh, hd = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    if s % block_q != 0 or s % block_k != 0:
        raise ValueError(f"sequence {s} must be divisible by blocks {block_q}/{block_k}")

    grid = (b, nh, s // block_q)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, block_q=block_q, block_k=block_k, causal=causal
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd), lambda bb, hh, qq: (bb, qq, hh, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda bb, hh, qq: (bb, 0, hh, 0)),
            pl.BlockSpec((1, s, 1, hd), lambda bb, hh, qq: (bb, 0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd), lambda bb, hh, qq: (bb, qq, hh, 0)),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(s: int, hd: int, block_q: int = 128, block_k: int = 128, elem: int = 4) -> int:
    """Estimated VMEM residency per grid point (perf-model helper):
    Q tile + K/V panels + fp32 accumulator + softmax state."""
    q_tile = block_q * hd * elem
    kv = 2 * s * hd * elem
    acc = block_q * hd * 4
    state = 2 * block_q * 4
    return q_tile + kv + acc + state
