//! End-to-end validation driver (EXPERIMENTS.md §E10).
//!
//! Trains the ~100M-parameter model for a few hundred steps on the
//! synthetic corpus, **distributed** over simulated devices — demonstrating
//! that all layers compose:
//!
//! 1. phase 1: DP2 × PP2 (4 devices, 1F1B-equivalent GPipe interpreter);
//! 2. §6 graph switch (fused-BSR weight repartitioning) to TP2 × PP2;
//! 3. phase 2 continues training — the loss curve must continue smoothly.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_e2e [STEPS]
//! ```

use hetu::config::RunConfig;
use hetu::coordinator::Trainer;
use hetu::engine::EngineStrategy;

fn main() -> hetu::Result<()> {
    let steps: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(100);
    let phase1 = steps / 2;
    let phase2 = steps - phase1;

    let cfg = RunConfig { steps, lr: 1e-3, ..RunConfig::default() };
    let s1 = EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 1);
    let s2 = EngineStrategy::uniform("tp2pp2", 1, 2, 2, 8, 2);

    println!("=== phase 1: {} steps under {} ===", phase1, s1.name);
    let mut trainer = Trainer::new(cfg, s1)?;
    let t0 = std::time::Instant::now();
    trainer.train(phase1)?;

    println!("=== graph switch (§6 fused BSR over the mesh) ===");
    let (msgs, elems) = trainer.switch(s2)?;
    println!("moved {elems} elements in {msgs} messages");

    println!("=== phase 2: {} steps under tp2pp2 ===", phase2);
    trainer.train(phase2)?;
    let wall = t0.elapsed().as_secs_f64();

    // loss curve (log every ~5%)
    let logs = trainer.logs();
    let stride = (logs.len() / 20).max(1);
    println!("\nstep  strategy   loss");
    for log in logs.iter().step_by(stride) {
        println!("{:>4}  {:<9}  {:.4}", log.step, log.strategy, log.loss);
    }
    if let Some(last) = logs.last() {
        println!("{:>4}  {:<9}  {:.4}", last.step, last.strategy, last.loss);
    }

    let (head, tail) = trainer.loss_improved()?;
    let tput = logs.len() as f64 / wall;
    println!("\nloss: {head:.4} -> {tail:.4}  |  {tput:.2} steps/s  |  total {wall:.1}s");
    assert!(tail < head, "training must reduce loss end-to-end");
    // Transparency note: per-step losses are batch-noisy (each step sees a
    // different motif mix), so step-to-step diffs are not a transparency
    // test. The exact check — a switched run's losses equal an unswitched
    // reference run's — is `engine_integration::
    // training_reduces_loss_and_switching_is_transparent` and
    // `tp_degree_resharding_switch_is_transparent`.
    let b = phase1 as usize;
    if b > 0 && b < logs.len() {
        let before: f32 =
            logs[..b].iter().rev().take(4).map(|l| l.loss).sum::<f32>() / 4f32.min(b as f32);
        let after: f32 =
            logs[b..].iter().take(4).map(|l| l.loss).sum::<f32>() / 4f32.min((logs.len() - b) as f32);
        println!("4-step mean across switch: {before:.4} -> {after:.4}");
        assert!(after < before + 2.0, "no blow-up across the switch");
    }
    println!("train_e2e OK");
    Ok(())
}
