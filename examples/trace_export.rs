//! Generate a Chrome/Perfetto trace from one traced engine step.
//!
//! ```sh
//! cargo run --release --example trace_export -- trace.json
//! ```
//!
//! Runs the lowered-C2 hetero encoding (2 uneven pipelines, TP tail) on
//! the threaded executor with §10 span tracing armed, so every span
//! carries real wall timestamps, then writes the step as Chrome
//! trace-event JSON — one track per mesh rank, flow arrows on the p2p
//! hand-off edges. Open the file at `chrome://tracing` or
//! <https://ui.perfetto.dev>. The CI trace-smoke step feeds the output
//! through `python3 -m json.tool` and `tools/trace_check.py`.

use hetu::coordinator::SyntheticCorpus;
use hetu::engine::{Engine, ExecMode};
use hetu::runtime::{native, Runtime};
use hetu::strategy::{tables, LowerOptions};

fn main() -> hetu::Result<()> {
    let path = std::env::args().nth(1).unwrap_or_else(|| "trace.json".to_string());
    let tiny = native::tiny_config();
    let lopts = LowerOptions { total_microbatches: 8, tp_degrees: vec![1, 2, 4] };
    let c2e = hetu::strategy::lower(&tables::hetu_c2_31h20(), &tiny, &lopts)?;
    let ndev = c2e.num_devices();
    let mut eng = Engine::with_runtime(Runtime::native(tiny), c2e, 42, 1e-3)?;
    eng.set_exec_mode(ExecMode::Threaded);
    eng.set_tracing(true);
    let mut corpus = SyntheticCorpus::new(17, tiny.vocab);
    let stats = eng.train_step(&mut |_p, _m| corpus.microbatch(tiny.batch, tiny.seq))?;
    let spans = eng.last_step_spans().len();
    let json = eng.export_chrome_trace()?;
    std::fs::write(&path, &json).map_err(|e| {
        hetu::Error::Engine(format!("trace_export: writing {path}: {e}"))
    })?;
    println!(
        "wrote {path}: {spans} spans over {ndev} ranks, wall makespan {:.3} ms \
         (loss {:.4})",
        stats.makespan_s * 1e3,
        stats.loss
    );
    if let Some(b) = stats.breakdown {
        println!(
            "breakdown [wall]: compute {:.3} ms, comm {:.3} ms, optim {:.3} ms, \
             bubble {:.3} ms",
            b.compute_s * 1e3,
            b.comm_s * 1e3,
            b.optim_s * 1e3,
            b.bubble_s * 1e3
        );
    }
    Ok(())
}
