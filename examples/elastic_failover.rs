//! Elastic failover (§7.2) at two levels:
//!
//! 1. **Real numerics**: train DP2×PP2 on 4 simulated devices, "fail" one
//!    pipeline's devices mid-run, §6-switch the surviving weights to a
//!    single-pipeline layout, and keep training — no restart, loss curve
//!    continues.
//! 2. **Paper scale**: replay the Fig 14 homogeneous trace (C1→C2→C3,
//!    32→31→24 H20s) for all four systems on the simulated cluster.
//!
//! ```sh
//! make artifacts && cargo run --release --example elastic_failover
//! ```

use hetu::config::RunConfig;
use hetu::coordinator::Trainer;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::elastic::{homogeneous_trace, run_scenario, System};
use hetu::engine::{EnginePipeline, EngineStage, EngineStrategy};

fn main() -> hetu::Result<()> {
    // ---- level 1: engine-scale failover
    println!("=== engine failover: dp2pp2 -> (GPU failure) -> pp2 ===");
    let cfg = RunConfig { steps: 12, lr: 1e-3, ..RunConfig::default() };
    let dp2 = EngineStrategy::uniform("dp2pp2", 2, 1, 2, 8, 1);
    // survivor layout: only devices 0,1 (pipeline 2's devices 2,3 are dead)
    let survivor = EngineStrategy {
        name: "pp2-survivor".into(),
        pipelines: vec![EnginePipeline {
            stages: vec![
                EngineStage { devices: vec![0], layers: (0, 4) },
                EngineStage { devices: vec![1], layers: (4, 8) },
            ],
            num_microbatches: 2,
        }],
        schedule: hetu::spec::schedule::ScheduleKind::GPipe,
    };
    let mut trainer = Trainer::new(cfg, dp2)?;
    trainer.train(6)?;
    let t0 = std::time::Instant::now();
    // devices 2,3 are dead: they are excluded as weight *sources*, so the
    // fused-BSR plan pulls every slice from the surviving replica
    let (msgs, elems) = trainer.switch_avoiding(survivor, &[2, 3])?;
    let reconf = t0.elapsed().as_secs_f64();
    println!(
        "reconfigured in {:.1} ms ({msgs} messages, {elems} elems moved) — no restart",
        reconf * 1e3
    );
    trainer.train(6)?;
    for log in trainer.logs().iter().step_by(2) {
        println!("step {:>3}  [{:<13}] loss {:.4}", log.step, log.strategy, log.loss);
    }
    let (head, tail) = trainer.loss_improved()?;
    // short run: assert sane continuation (no blow-up) across the failover
    assert!(tail < head + 1.0, "loss must not blow up across the failover: {head} -> {tail}");
    println!("loss {head:.4} -> {tail:.4} across the failure. OK\n");

    // ---- level 2: paper-scale trace
    println!("=== Fig 14 homogeneous trace (simulated 32x H20) ===");
    let cm = CostModel::new(ModelCfg::llama_32b());
    let sc = homogeneous_trace();
    for sys in [System::Hetu, System::DeepSpeed, System::Megatron, System::Oobleck] {
        let reps = run_scenario(&sc, &cm, sys, 64, 4096)?;
        print!("{sys:?}:");
        for r in &reps {
            print!("  {}={:.2}s(+{:.0}s reconf)", r.name, r.step_s, r.reconfig_s);
        }
        println!();
    }
    Ok(())
}
