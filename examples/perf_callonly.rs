//! §Perf: isolate PJRT compute from engine coordination.
use hetu::engine::{Engine, EngineStrategy};
use hetu::runtime::HostTensor;
use std::time::Instant;
fn main() {
    let eng = Engine::new("artifacts", EngineStrategy::uniform("solo",1,1,1,8,1), 42, 1e-3).unwrap();
    let c = eng.runtime.config;
    let dev = &eng.mesh.devices[0];
    let mut inputs: Vec<&HostTensor> = vec![];
    for p in hetu::engine::BLOCK_PARAMS { inputs.push(dev.get(&format!("L0.{p}")).unwrap()); }
    let x = HostTensor::zeros(vec![c.batch, c.seq, c.hidden]);
    inputs.push(&x);
    eng.runtime.call_refs("block_fwd_tp1", &inputs).unwrap();
    let t = Instant::now();
    let n = 16;
    for _ in 0..n { eng.runtime.call_refs("block_fwd_tp1", &inputs).unwrap(); }
    println!("block_fwd_tp1: {:.1}ms/call", t.elapsed().as_secs_f64()*1e3/n as f64);
    // bwd
    let dy = HostTensor::zeros(vec![c.batch, c.seq, c.hidden]);
    let mut binp = inputs.clone(); binp.push(&dy);
    eng.runtime.call_refs("block_bwd_tp1", &binp).unwrap();
    let t = Instant::now();
    for _ in 0..n { eng.runtime.call_refs("block_bwd_tp1", &binp).unwrap(); }
    println!("block_bwd_tp1: {:.1}ms/call", t.elapsed().as_secs_f64()*1e3/n as f64);
    // head
    let tgt = HostTensor::i32(vec![c.batch, c.seq], vec![1; c.batch*c.seq]).unwrap();
    let hin = vec![dev.get("gf").unwrap(), dev.get("wout").unwrap(), &x, &tgt];
    eng.runtime.call_refs("head_step", &hin).unwrap();
    let t = Instant::now();
    for _ in 0..n { eng.runtime.call_refs("head_step", &hin).unwrap(); }
    println!("head_step: {:.1}ms/call", t.elapsed().as_secs_f64()*1e3/n as f64);
}
