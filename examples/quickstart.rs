//! Quickstart: train the tiny (~100M-param) model on one simulated device.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT HLO artifacts through PJRT, runs 10 optimizer steps on the
//! synthetic corpus, and prints the loss curve. This exercises the full
//! L1→L2→L3 stack with zero parallelism — the baseline every distributed
//! configuration must numerically match.

use hetu::config::RunConfig;
use hetu::coordinator::Trainer;
use hetu::engine::EngineStrategy;

fn main() -> hetu::Result<()> {
    let cfg = RunConfig { steps: 8, lr: 1e-3, ..RunConfig::default() };
    let strategy = EngineStrategy::uniform("quickstart-dp1", 1, 1, 1, 8, 2);
    let mut trainer = Trainer::new(cfg, strategy)?;
    let c = trainer.engine.runtime.config;
    let params = hetu::costmodel::ModelCfg::tiny_100m().params_per_layer() * c.layers as u64
        + 2 * (c.vocab * c.hidden) as u64; // untied embedding + LM head
    println!(
        "model: {} layers x hidden {} (vocab {}) — ~{:.0}M params",
        c.layers,
        c.hidden,
        c.vocab,
        params as f64 / 1e6,
    );
    trainer.train(8)?;
    for log in trainer.logs() {
        println!("step {:>3}  loss {:.4}  ({:.0} ms)", log.step, log.loss, log.wall_s * 1e3);
    }
    let (head, tail) = trainer.loss_improved()?;
    println!("loss {head:.4} -> {tail:.4} ({})", if tail < head { "improving" } else { "FLAT" });
    Ok(())
}
