//! Mixed-length training (§7.3): the data substrate and the per-step
//! strategy economics.
//!
//! Samples CommonCrawl-like 200K-token steps at 32K context, shows the
//! length distribution (97% under 8K), the packing baseline, the
//! HotSPa/Hetu-A buckets, and Hetu-B's per-step heterogeneous dispatch +
//! strategy selection with simulated per-step times for all five systems.
//!
//! ```sh
//! cargo run --release --example mixed_length [STEPS]
//! ```

use hetu::cluster::Cluster;
use hetu::costmodel::{CostModel, ModelCfg};
use hetu::data::{bucketize, dispatch_hetu_b, pack_sequences, sample_step, Corpus, PipeClass};
use hetu::testutil::Rng;

fn main() -> hetu::Result<()> {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10);
    let cluster = Cluster::h20(32);
    let cm = CostModel::new(ModelCfg::llama_32b());
    let mut rng = Rng::new(2024);

    let mut totals = [0f64; 3]; // megatron, hotspa, hetu_b
    for step in 0..steps {
        let batch = sample_step(&mut rng, Corpus::CommonCrawl, 200_000, 32768);
        let under8k = batch.seq_lens.iter().filter(|&&l| l < 8192).count() as f64
            / batch.seq_lens.len() as f64;
        let packed = pack_sequences(&batch.seq_lens, 32768).len() as u64;
        let buckets = bucketize(&batch.seq_lens, &[4096, 16384, 32768]);
        let dispatch = dispatch_hetu_b(
            &batch.seq_lens,
            &[
                PipeClass { max_seq: 32768, tokens_per_s: 16.0 }, // long pipeline (TP16)
                PipeClass { max_seq: 4096, tokens_per_s: 4.0 },
                PipeClass { max_seq: 4096, tokens_per_s: 4.0 },
                PipeClass { max_seq: 4096, tokens_per_s: 4.0 },
                PipeClass { max_seq: 4096, tokens_per_s: 4.0 },
            ],
        );

        // per-system times
        let mg_cfg = hetu::baselines::megatron::table9(32768).unwrap();
        let t_mg = hetu::baselines::megatron::step_time(&cluster, &cm, mg_cfg, packed, 32768)?;
        let t_hotspa =
            hetu::baselines::hotspa::step_time(&cluster, &cm, &batch, 32768, &|_, _| 2.0)?;
        let t_hetu_b = hetu::figures::hetu_b_step(&cluster, &cm, &batch, 32768)?;
        totals[0] += t_mg;
        totals[1] += t_hotspa;
        totals[2] += t_hetu_b;

        println!(
            "step {:>3}: {:>3} seqs, max {:>5}, {:>4.1}% <8K | packed->{:>2} windows | \
             buckets {:>3}/{:>2}/{:>2} | Hetu-B long-pipe {:>2} seqs | \
             Mg {:>5.1}s HotSPa {:>5.1}s Hetu-B {:>5.1}s",
            step,
            batch.seq_lens.len(),
            batch.max_len(),
            under8k * 100.0,
            packed,
            buckets[0].len(),
            buckets[1].len(),
            buckets[2].len(),
            dispatch[0].len(),
            t_mg,
            t_hotspa,
            t_hetu_b,
        );
    }
    println!(
        "\nmeans over {steps} steps: Megatron {:.2}s | HotSPa {:.2}s | Hetu-B {:.2}s",
        totals[0] / steps as f64,
        totals[1] / steps as f64,
        totals[2] / steps as f64
    );
    assert!(totals[2] <= totals[1] * 1.02, "Hetu-B should not lose to HotSPa");
    // NOTE: with the flat 2s switch cost used here HotSPa may trail packed
    // Megatron on unlucky short runs; the proper comparison (per-pair fused
    // switch costs, more steps) is Fig 15 (`cargo bench --bench
    // fig15_mixed_length`), where HotSPa beats the packed baselines.
    assert!(totals[2] < totals[0], "Hetu-B must beat packed Megatron");
    Ok(())
}
