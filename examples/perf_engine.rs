//! §Perf harness: engine step latency + conversion overhead breakdown.
use hetu::engine::{Engine, EngineStrategy};
use hetu::coordinator::SyntheticCorpus;
use std::time::Instant;
fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "solo".into());
    let strat = match which.as_str() {
        "tp2" => EngineStrategy::uniform("tp2", 1, 2, 1, 8, 1),
        "pp2" => EngineStrategy::uniform("pp2", 1, 1, 2, 8, 1),
        _ => EngineStrategy::uniform("solo", 1, 1, 1, 8, 1),
    };
    let mut eng = Engine::new("artifacts", strat, 42, 1e-3).unwrap();
    let cfg = eng.runtime.config;
    let mut corpus = SyntheticCorpus::new(7, cfg.vocab);
    // warmup
    eng.train_step(&mut |_,_| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
    let mut best = f64::INFINITY; let mut total = 0.0;
    let iters = 3;
    for _ in 0..iters {
        let t = Instant::now();
        eng.train_step(&mut |_,_| corpus.microbatch(cfg.batch, cfg.seq)).unwrap();
        let dt = t.elapsed().as_secs_f64();
        best = best.min(dt); total += dt;
    }
    println!("{which}: mean {:.3}s best {:.3}s (wire {} elems/step)", total/iters as f64, best, eng.mesh.wire_elems / (iters as u64 + 1));
}
